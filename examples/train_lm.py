"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic corpus, with checkpointing and restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]
"""

import argparse
import dataclasses

from repro.configs import get
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="tiny config for a fast smoke run")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        cfg = get("qwen3-8b").reduced()
        tc = TrainConfig(seq_len=64, global_batch=8, steps=args.steps,
                         checkpoint_every=100, checkpoint_dir=args.ckpt,
                         log_every=20)
    else:
        # ~100M params: 12 layers x 512 wide, GQA + qk-norm (qwen3 family).
        cfg = dataclasses.replace(
            get("qwen3-8b"), num_layers=12, d_model=512, num_heads=8,
            num_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32768)
        tc = TrainConfig(seq_len=256, global_batch=16, steps=args.steps,
                         checkpoint_every=100, checkpoint_dir=args.ckpt,
                         log_every=10)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"steps={tc.steps}")
    oc = OptConfig(peak_lr=1e-3, min_lr=1e-4,
                   warmup_steps=max(tc.steps // 20, 5),
                   total_steps=tc.steps)
    out = Trainer(cfg, tc, oc).run()
    h = out["history"]
    print(f"\nloss: {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"({len(h)} steps, restartable from {tc.checkpoint_dir})")


if __name__ == "__main__":
    main()
