"""Beyond-paper analysis: replacement-policy headroom and the TPU VMEM
working-set analogue.

  PYTHONPATH=src python examples/dispersion_analysis.py
"""

from repro import rvv
from repro.core import planner
from repro.kernels import ops

print("== Belady-OPT headroom over the paper's FIFO (hit rates) ==")
b = rvv.BENCHMARKS["pathfinder"]
built = b.build(**b.paper_params)
res = planner.policy_headroom(built.program, capacities=(3, 4, 5, 6))
print(f"{'cap':>4} {'fifo':>7} {'lru':>7} {'opt':>7}")
for cap in (3, 4, 5, 6):
    print(f"{cap:>4} {res['fifo'][cap]:7.3f} {res['lru'][cap]:7.3f} "
          f"{res['opt'][cap]:7.3f}")

print("\n== VMEM accumulator working set vs HBM traffic (granite-8b MLP) ==")
print(f"{'W':>3} {'HBM GB':>8} {'VMEM MB':>8}   (ideal = "
      f"{ops.hbm_traffic_model(8192, 14336, 4096, block_m=128, block_k=512, working_set=1)['ideal'] / 1e9:.1f} GB)")
for w in (1, 2, 4, 8, 16):
    t = ops.hbm_traffic_model(8192, 14336, 4096, block_m=128, block_k=512,
                              working_set=w)
    print(f"{w:>3} {t['grouped'] / 1e9:8.2f} {t['vmem_acc_bytes'] / 1e6:8.1f}")
print("more physical 'registers' (VMEM tiles) => less memory traffic —")
print("the paper's Fig 4 economics at the next level of the hierarchy.")
