"""Quickstart: the paper's Register Dispersion study in ~40 lines.

Builds the GemV kernel, proves dispersion is semantics-preserving, sweeps
cVRF sizes (Fig 4) through the declarative ``repro.api`` front door, finds
the minimal working set (Fig 5), and prints the area/power verdict
(Figs 2/8).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api, metrics, rvv
from repro.core import interpreter, planner, policies

# 1. One Session owns every cache (built kernels, prepared traces) and
#    plans sweep execution; build a paper kernel at a custom size.
session = api.Session()
built = session.built("gemv", params=dict(m=128, k=256))
prog = built.program
print(f"gemv: {prog.num_instructions} instructions, "
      f"{len(prog.active_vregs())} active vector registers")

# 2. Register Dispersion never changes results (cVRF of 4, FIFO).
full = interpreter.run(prog)
rvv.check(built, full.memory)
disp = interpreter.run_dispersed(prog, capacity=4, policy=policies.FIFO)
np.testing.assert_array_equal(full.memory, disp.memory)
print(f"dispersed execution bit-identical "
      f"(hit rate {disp.vrf_hits / (disp.vrf_hits + disp.vrf_misses):.3f})")

# 3. Fig 4: performance + hit rate vs cVRF size — one declarative sweep.
caps = [3, 4, 5, 6, 7, 8, 16, 32]
res = session.run(api.Sweep(kernels=["gemv"], capacity=caps,
                            kernel_params=dict(m=128, k=256)))
full_cycles = res.value("cycles", capacity=32)
for c in caps:
    cyc = res.value("cycles", capacity=c)
    bar = "#" * int(40 * full_cycles / cyc)
    print(f"  cVRF {c:2d}: perf {full_cycles / cyc:5.3f} "
          f"hit {res.value('hit_rate', capacity=c):5.3f} {bar}")

# 4. Fig 5: smallest cVRF with >95% hit rate.
plan = planner.min_registers_for_hit_rate(prog)
print(f"min registers for >95% hit rate: {plan.min_capacity}")

# 5. Figs 2/8: the hardware verdict for cVRF-8 vs the full VRF — the
#    area/power models and baseline-relative savings are metrics evaluated
#    over the sweep grid (docs/metrics.md), not hand-rolled loops.
head = metrics.area_headline(n_full=32, n_cvrf=8)
r = (res.derive("savings_pct", of="vpu_area",
                baseline=dict(capacity=32), out="vpu_area_saving")
        .derive("savings_pct", of="application_power",
                baseline=dict(capacity=32), out="power_saving")
        .derive("speedup", baseline=dict(capacity=32)))
print(f"VPU area  -{r.value('vpu_area_saving', capacity=8):.0f}%   "
      f"total area -{head['total_area_saving_pct']:.0f}%   "
      f"power -{r.value('power_saving', capacity=8):.0f}%   "
      f"perf {r.value('speedup', capacity=8):.3f}x")

# 6. The design-space verdict in one query: the non-dominated
#    (area, cycles) trade-off over every swept cVRF size.
front = r.pareto(x="total_area", y="cycles")
print("area-cycles front:",
      " -> ".join(f"cVRF {f['capacity']} ({f['total_area'] / 1e6:.2f}Mau)"
                  for f in front))
