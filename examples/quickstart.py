"""Quickstart: the paper's Register Dispersion study in ~40 lines.

Builds the GemV kernel, proves dispersion is semantics-preserving, sweeps
cVRF sizes (Fig 4) through the declarative ``repro.api`` front door, finds
the minimal working set (Fig 5), and prints the area/power verdict
(Figs 2/8).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api, rvv
from repro.core import costmodel, interpreter, planner, policies, simulator

# 1. One Session owns every cache (built kernels, prepared traces) and
#    plans sweep execution; build a paper kernel at a custom size.
session = api.Session()
built = session.built("gemv", params=dict(m=128, k=256))
prog = built.program
print(f"gemv: {prog.num_instructions} instructions, "
      f"{len(prog.active_vregs())} active vector registers")

# 2. Register Dispersion never changes results (cVRF of 4, FIFO).
full = interpreter.run(prog)
rvv.check(built, full.memory)
disp = interpreter.run_dispersed(prog, capacity=4, policy=policies.FIFO)
np.testing.assert_array_equal(full.memory, disp.memory)
print(f"dispersed execution bit-identical "
      f"(hit rate {disp.vrf_hits / (disp.vrf_hits + disp.vrf_misses):.3f})")

# 3. Fig 4: performance + hit rate vs cVRF size — one declarative sweep.
caps = [3, 4, 5, 6, 7, 8, 16, 32]
res = session.run(api.Sweep(kernels=["gemv"], capacity=caps,
                            kernel_params=dict(m=128, k=256)))
full_cycles = res.value("cycles", capacity=32)
for c in caps:
    cyc = res.value("cycles", capacity=c)
    bar = "#" * int(40 * full_cycles / cyc)
    print(f"  cVRF {c:2d}: perf {full_cycles / cyc:5.3f} "
          f"hit {res.value('hit_rate', capacity=c):5.3f} {bar}")

# 4. Fig 5: smallest cVRF with >95% hit rate.
plan = planner.min_registers_for_hit_rate(prog)
print(f"min registers for >95% hit rate: {plan.min_capacity}")

# 5. Figs 2/8: the hardware verdict for cVRF-8 vs the full VRF.
full_a = costmodel.cpu_area(32)
cvrf_a = costmodel.cpu_area(8, dispersed=True)
c8 = simulator.simulate_one(prog, 8)
c32 = simulator.simulate_one(prog, 32)
p8 = costmodel.application_power(c8, 8, c8["cycles"], dispersed=True)
p32 = costmodel.application_power(c32, 32, c32["cycles"])
print(f"VPU area  -{100 * (1 - cvrf_a.vpu / full_a.vpu):.0f}%   "
      f"total area -{100 * (1 - cvrf_a.total / full_a.total):.0f}%   "
      f"power -{100 * (1 - p8['total'] / p32['total']):.0f}%   "
      f"perf {float(c32['cycles']) / float(c8['cycles']):.3f}x")
