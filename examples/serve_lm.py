"""Batched serving with continuous batching + the dispersed KV page pool.

Trains a tiny model briefly so generations are non-degenerate, then serves
a stream of requests and prints the dispersion statistics of the KV pool
(the paper's mechanism at page granularity).

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get
from repro.core import policies
from repro.optim import OptConfig
from repro.serve import DispersedKVPool, PagePoolConfig, Request, ServeEngine
from repro.train import TrainConfig, Trainer

cfg = get("phi3-mini-3.8b").reduced()
tc = TrainConfig(seq_len=64, global_batch=8, steps=40, checkpoint_every=999,
                 checkpoint_dir="/tmp/repro_serve_ckpt", log_every=20)
out = Trainer(cfg, tc, OptConfig(peak_lr=3e-3, warmup_steps=4,
                                 total_steps=40)).run()
params = out["state"]["params"]

engine = ServeEngine(cfg, params, slots=4, max_len=96, temperature=0.8)
requests = [Request(prompt=list(np.random.default_rng(i).integers(
    1, cfg.vocab_size, 8)), max_new_tokens=16) for i in range(10)]
engine.run(requests)
for i, r in enumerate(requests[:4]):
    print(f"req{i}: prompt={r.prompt[:4]}... -> {r.out}")
print(f"all {len(requests)} requests served with {engine.slots} slots "
      "(continuous batching)")

# Dispersed KV pool demo: bounded hot memory, FIFO spill to the cold region.
pool = DispersedKVPool(PagePoolConfig(
    num_logical_pages=64, num_hot_pages=8,
    page_shape=(16, cfg.num_kv_heads, cfg.head_dim),
    policy=policies.FIFO))
rng = np.random.default_rng(0)
for step in range(400):
    tail = min(step // 8, 63)
    pool.write(tail, pool.read(tail))
    for p in rng.integers(0, max(tail, 1), 2):
        pool.read(int(p))
st = pool.stats()
print(f"dispersed KV pool: hit rate {st['hit_rate']:.3f} with "
      f"{st['hot_bytes'] / 1e3:.0f} kB hot vs {st['cold_bytes'] / 1e3:.0f} kB"
      f" logical (spills={st['spills']})")
