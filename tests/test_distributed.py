"""Multi-device integration tests (subprocess-isolated: jax fixes its device
count at first import, and the assignment requires smoke tests to see ONE
device — so each test spawns a fresh interpreter with forced host devices).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_shardings_all_archs_divisible():
    """Every arch's full-size params get valid shardings on a 4x2 mesh."""
    _run("""
        import jax
        from repro.configs import ARCHS
        from repro.launch import sharding as shr, specs
        from repro.models import common
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        common.set_mesh(mesh)
        for name in ARCHS:
            params, _ = specs.state_specs(name)
            sh = shr.params_shardings(params, mesh)
            flat = jax.tree.leaves_with_path(sh) if hasattr(jax.tree, 'leaves_with_path') else None
            # shard_shape raises if any dim is not divisible
            for (path, leaf), (_, s) in zip(
                    jax.tree_util.tree_flatten_with_path(params)[0][:9999],
                    jax.tree_util.tree_flatten_with_path(sh)[0]):
                s.shard_shape(leaf.shape)
            print(name, "ok")
    """)


def test_train_cell_compiles_on_debug_mesh():
    """End-to-end dry-run plumbing (specs -> shardings -> jit lower+compile)
    on a 2x2 mesh with a reduced arch."""
    _run("""
        import dataclasses, jax
        import repro.configs.registry as reg
        from repro.configs import get
        from repro.configs.registry import ShapeConfig
        from repro.launch import sharding as shr, specs
        from repro.models import common, get_model
        from repro.optim import adamw
        from repro.train.train_step import make_train_step

        cfg = dataclasses.replace(get("qwen3-8b").reduced(), name="dbg")
        reg.ARCHS["dbg"] = cfg
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        common.set_mesh(mesh)
        shape = ShapeConfig("t", 32, 4, "train")
        sp = specs.input_specs("dbg", shape)
        psh = shr.params_shardings(sp["params"], mesh)
        bsh = shr.batch_shardings(sp["batch"], mesh, "train")
        osh = shr.opt_shardings(sp["opt"], psh, mesh)
        step = make_train_step(cfg, adamw.OptConfig(), microbatches=2)
        fn = jax.jit(step, in_shardings=(psh, osh, None, bsh),
                     out_shardings=(psh, osh, None, None))
        c = fn.lower(sp["params"], sp["opt"], None, sp["batch"]).compile()
        assert c.cost_analysis() is not None
        print("compiled ok")
    """, devices=4)


def test_decode_cell_compiles_on_debug_mesh():
    _run("""
        import dataclasses, jax
        import repro.configs.registry as reg
        from repro.configs import get
        from repro.configs.registry import ShapeConfig
        from repro.launch import sharding as shr, specs
        from repro.models import common, get_model

        cfg = dataclasses.replace(get("granite-8b").reduced(), name="dbg")
        reg.ARCHS["dbg"] = cfg
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        common.set_mesh(mesh)
        shape = ShapeConfig("d", 64, 4, "decode")
        sp = specs.input_specs("dbg", shape)
        psh = shr.params_shardings(sp["params"], mesh)
        bsh = shr.batch_shardings(sp["batch"], mesh, "decode")
        csh = shr.cache_shardings(sp["cache"], mesh)
        model = get_model(cfg)
        fn = jax.jit(lambda p, c, b: model.decode_step(p, c, b),
                     in_shardings=(psh, csh, bsh), out_shardings=(None, csh))
        fn.lower(sp["params"], sp["cache"], sp["batch"]).compile()
        print("compiled ok")
    """, devices=4)


def test_sharded_train_numerics_match_single_device():
    """The same train step computes the same loss sharded vs unsharded."""
    _run("""
        import dataclasses, jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get
        from repro.launch import sharding as shr
        from repro.models import common
        from repro.optim import adamw
        from repro.train.train_step import make_train_step

        cfg = dataclasses.replace(get("phi3-mini-3.8b").reduced(),
                                  dtype="float32")
        from repro.models import get_model
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "targets": jnp.ones((4, 16), jnp.int32),
                 "positions": jnp.broadcast_to(jnp.arange(16)[None], (4, 16))}
        step = make_train_step(cfg, adamw.OptConfig(), 1)
        _, _, _, m_plain = jax.jit(step)(params, opt, None, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        common.set_mesh(mesh)
        psh = shr.params_shardings(params, mesh)
        osh = shr.opt_shardings(opt, psh, mesh)
        bsh = shr.batch_shardings(batch, mesh, "train")
        fn = jax.jit(step, in_shardings=(psh, osh, None, bsh),
                     out_shardings=(psh, osh, None, None))
        _, _, _, m_shard = fn(params, opt, None, batch)
        np.testing.assert_allclose(float(m_plain["loss"]),
                                   float(m_shard["loss"]), rtol=1e-5)
        print("losses match:", float(m_plain["loss"]))
    """, devices=4)


def test_elastic_checkpoint_restore_across_meshes():
    """Save under a (2,2) mesh, restore under (4,1) with re-sharding."""
    _run("""
        import jax, numpy as np, tempfile
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer

        mesh_a = jax.make_mesh((2, 2), ("data", "model"))
        sh_a = NamedSharding(mesh_a, P("data", "model"))
        x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           sh_a)
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, {"x": x}, blocking=True)

        mesh_b = jax.make_mesh((4, 1), ("data", "model"))
        sh_b = NamedSharding(mesh_b, P("data", "model"))
        step, restored = ck.restore(
            {"x": x}, shard_fn=lambda k, a: jax.device_put(a, sh_b))
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.arange(64).reshape(8, 8))
        assert restored["x"].sharding == sh_b
        print("elastic restore ok")
    """, devices=4)


def test_multipod_mesh_axes():
    _run("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh(multi_pod=True)
        assert m.axis_names == ("pod", "data", "model")
        assert m.devices.shape == (2, 16, 16)
        s = make_production_mesh()
        assert s.axis_names == ("data", "model")
        print("meshes ok")
    """, devices=512)
