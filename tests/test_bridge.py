"""Trace-from-model bridge: certification truth table + lowering laws.

Three tiers, mirroring the hand-written kernels' test structure:

1. A fold-certification truth table per (model, layer kind): every tile
   program the bridge emits for a registry model must carry a certifiable
   fold plan at the 4 KB pin geometry — the whole point of way-span
   padding.  The table also pins WHICH layer kinds each architecture
   lowers to (attention-only, Mamba scan, hybrid, MoE).
2. Property tests (seeded; hypothesis widens the shapes when available):
   the ``repeat``-stride emission is row-for-row identical to a naively
   unrolled emission with literal addresses, and signature-based dedup is
   lawful — equal signatures always rebuild the identical trace (so
   merged layers share counters by construction) while distinct
   signatures never share a kernel name.
3. One end-to-end ``Session.run``: >= 3 registry models lowered through
   the ``network`` axis into a single >= 100-point sweep whose compile
   count is pinned by the (shape bucket x L1 geometry) plan groups.

Bridge lowering never runs at module import time: the conformance matrix
in test_golden_counters parametrizes over ``rvv.BENCHMARKS`` at
collection, and registering ``net:*`` kernels that early would widen it.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except Exception:                                     # pragma: no cover
    HAVE_HYP = False

from repro import api
from repro.core import folding, isa, simulator

# The 4 KB direct-er pin geometry (64 sets x 2 ways) used across the
# docs' certification examples; plan() warm-up derives from it.
PIN_SETS, PIN_WAYS = 64, 2
PIN_WARM = folding.warm_lines_for(PIN_SETS, PIN_WAYS)

# Program columns that define the instruction stream (everything except
# the memory image and periodicity metadata).
ROW_FIELDS = ("op", "vd", "vs1", "vs2", "addr", "imm", "cost_override")


def _rows(program):
    return {f: getattr(program, f) for f in ROW_FIELDS}


def _assert_same_rows(a, b):
    for f in ROW_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# 1. Certification truth table per (model, layer kind).
# ---------------------------------------------------------------------------

# Which layer kinds each architecture must lower to, and whether the
# representative tile program of that kind certifies at the pin geometry.
# All True today — way-span padding is the lowering contract; a False here
# would mean a generated program regressed to somier-style inexactness.
CERT_TRUTH = {
    "granite-8b": {"gemm": True, "attn": True},
    "falcon-mamba-7b": {"gemm": True, "scan": True},
    "recurrentgemma-2b": {"gemm": True, "attn": True, "scan": True},
    "deepseek-v2-lite-16b": {"gemm": True, "attn": True},
}

_NETS: dict = {}
_PROGRAMS: dict = {}


def _lowered(model):
    if model not in _NETS:
        from repro import bridge
        _NETS[model] = bridge.lower_network(model)
    return _NETS[model]


def _tile_program(unit):
    if unit.kernel not in _PROGRAMS:
        from repro import bridge
        build = {"gemm": bridge.build_gemm, "attn": bridge.build_attn,
                 "scan": bridge.build_scan}[unit.kind]
        _PROGRAMS[unit.kernel] = build(**unit.params).program
    return _PROGRAMS[unit.kernel]


@pytest.mark.parametrize("model", sorted(CERT_TRUTH))
def test_certification_truth_table(model):
    net = _lowered(model)
    by_kind: dict = {}
    for u in net.units:
        by_kind.setdefault(u.kind, u)
    assert set(by_kind) == set(CERT_TRUTH[model]), model
    for kind, want in sorted(CERT_TRUTH[model].items()):
        p = _tile_program(by_kind[kind])
        plan = folding.plan(p, warm_lines=PIN_WARM)
        got = plan is not None and plan.certifiable
        assert got == want, (model, kind, by_kind[kind].kernel)


def test_lowering_is_deduplicated_and_scaled():
    """Dedup invariants the network report relies on: one unit per unique
    signature, instance counts preserved, positive macro factors."""
    net = _lowered("deepseek-v2-lite-16b")
    sigs = [(u.kind,) + u.shape for u in net.units]
    assert len(sigs) == len(set(sigs))
    assert len(net.kernels) == len(net.units) < net.num_instances
    assert all(u.macro_factor > 0 for u in net.units)
    # merged labels stay attributable: every unit keeps its layer labels
    assert all(u.labels for u in net.units)


# ---------------------------------------------------------------------------
# 2. Property: repeat emission == naive unrolled emission.
# ---------------------------------------------------------------------------

GEMM_SHAPES = [(1, 1, 1, 8), (2, 2, 16, 16), (3, 1, 7, 24), (2, 3, 5, 8),
               (4, 2, 33, 16)]
SCAN_SHAPES = [(1, 8), (3, 64), (7, 24), (12, 128)]


def _check_gemm_unroll(tiles, mt, k, n):
    from repro import bridge
    rolled = bridge.build_gemm(tiles=tiles, mt=mt, k=k, n=n)
    flat = bridge.build_gemm(tiles=tiles, mt=mt, k=k, n=n, unroll=True)
    _assert_same_rows(rolled.program, flat.program)
    np.testing.assert_array_equal(rolled.program.memory, flat.program.memory)
    assert not flat.program.repeats
    if max(tiles, mt, k, n // isa.VL_ELEMS) > 1:   # count-1 loops drop out
        assert rolled.program.repeats


def _check_scan_unroll(steps, width):
    from repro import bridge
    rolled = bridge.build_scan(steps=steps, width=width)
    flat = bridge.build_scan(steps=steps, width=width, unroll=True)
    _assert_same_rows(rolled.program, flat.program)
    np.testing.assert_array_equal(rolled.program.memory, flat.program.memory)
    assert not flat.program.repeats
    if max(steps, width // isa.VL_ELEMS) > 1:
        assert rolled.program.repeats


@pytest.mark.parametrize("tiles,mt,k,n", GEMM_SHAPES)
def test_gemm_repeat_equals_unrolled(tiles, mt, k, n):
    _check_gemm_unroll(tiles, mt, k, n)


@pytest.mark.parametrize("steps,width", SCAN_SHAPES)
def test_scan_repeat_equals_unrolled(steps, width):
    _check_scan_unroll(steps, width)


if HAVE_HYP:

    @settings(max_examples=25, deadline=None)
    @given(tiles=st.integers(1, 4), mt=st.integers(1, 3),
           k=st.integers(1, 40), n=st.integers(1, 4).map(lambda c: 8 * c))
    def test_gemm_repeat_equals_unrolled_hyp(tiles, mt, k, n):
        _check_gemm_unroll(tiles, mt, k, n)

    @settings(max_examples=25, deadline=None)
    @given(steps=st.integers(1, 10),
           width=st.integers(1, 24).map(lambda c: 8 * c))
    def test_scan_repeat_equals_unrolled_hyp(steps, width):
        _check_scan_unroll(steps, width)


# ---------------------------------------------------------------------------
# 2b. Property: signature dedup is lawful.
# ---------------------------------------------------------------------------

def _random_op(g):
    from repro.bridge import LayerOp
    kind = ("gemm", "attn", "scan")[g.integers(3)]
    if kind == "gemm":
        shape = (int(g.integers(1, 8192)), int(g.integers(1, 8192)))
    elif kind == "attn":
        shape = (int(g.integers(1, 64)), int(g.integers(8, 256)))
    else:
        shape = (int(g.integers(1, 16384)),)
    return LayerOp(kind, f"layer{g.integers(1000)}", shape,
                   int(g.integers(1, 64)))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dedup_never_merges_different_programs(seed):
    """tile_for is a pure function of the signature: ops with equal
    signatures map to one kernel name AND identical build kwargs (so the
    registered program — hence its counters — is unique per name), while
    ops with different signatures never share a name."""
    from repro.bridge import lower
    g = np.random.default_rng(seed)
    ops = [_random_op(g) for _ in range(40)]
    by_name: dict = {}
    for op in ops:
        name, kwargs, macro = lower.tile_for(op)
        assert macro > 0
        prev = by_name.setdefault(name, (op.signature, kwargs))
        assert prev == (op.signature, kwargs), name
    names = {op.signature: lower.tile_for(op)[0] for op in ops}
    assert len(set(names.values())) == len(names)


def test_registered_builds_are_deterministic():
    """Rebuilding from a unit's stored kwargs reproduces the trace
    bit-for-bit — the foundation of `exist_ok` re-registration: whichever
    model registers a shared-signature kernel first, the program (and so
    every counter) is the same."""
    net = _lowered("granite-8b")
    u = next(u for u in net.units if u.kind == "gemm")
    from repro import bridge
    a = bridge.build_gemm(**u.params).program
    b = bridge.build_gemm(**u.params).program
    _assert_same_rows(a, b)
    np.testing.assert_array_equal(a.memory, b.memory)


# ---------------------------------------------------------------------------
# 3. End-to-end: >= 3 models, one Session.run, compile count pinned.
# ---------------------------------------------------------------------------

def test_network_axis_plans_models_as_one_sweep():
    ses = api.Session()
    sweep = api.Sweep(
        network=("granite-8b", "qwen3-8b", "falcon-mamba-7b"),
        capacity=(3, 4, 8, 32), policy=("fifo", "lru"),
        l1_geometry=((PIN_SETS, PIN_WAYS),), fold=True)
    # lowering happened in __post_init__: the kernel axis is the union of
    # the three models' deduplicated net:* kernels
    assert len(sweep.kernels) >= 10
    assert all(k.startswith("net:") for k in sweep.kernels)
    res = ses.run(sweep)

    assert res.meta["points"] >= 100
    assert [n["model"] for n in res.meta["networks"]] == list(sweep.network)
    for n in res.meta["networks"]:
        assert n["instances"] > n["units"] > 0

    # The compile pin: programs grow with the model mix, compiles stay at
    # (shape bucket x L1 geometry).  Engine executables are cached per
    # process, so <=; the group count itself is the structural bound.
    groups = {(g["l1_geometry"], g["bucket"]) for g in res.meta["plan"]}
    planned = {k for g in res.meta["plan"] for k in g["kernels"]}
    assert planned == set(sweep.kernels)
    assert res.meta["compiles"] <= len(groups) <= 4
    assert res.meta["dispatches"] >= len(sweep.kernels)

    # every point folded AND certified exact — the padded-plane contract
    assert res.data["fold_exact"].all()

    # report rows: one per (model, non-kernel point), monotone footprint
    from repro import bridge
    rows = bridge.network_report(res.derive("scaled_cycles"),
                                 list(getattr(sweep, "_lowered")))
    assert len(rows) == 3 * (res.meta["points"] // len(sweep.kernels))
    assert all(r["scaled_cycles_total"] > 0 for r in rows)
    assert all(r["footprint_bytes"] == r["capacity"] * 32 for r in rows)
