"""Substrate tests: data pipeline, optimizer, checkpoint, fault tolerance,
serving engine, dispersed KV pool."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import policies
from repro.data import DataConfig, SyntheticCorpus
from repro.optim import adamw
from repro.runtime import Heartbeat, RestartPolicy, StragglerPolicy
from repro.runtime.fault_tolerance import HeartbeatRecord
from repro.serve import (DispersedKVPool, PagePoolConfig, Request,
                         ServeEngine)


# ------------------------------------------------------------------- data --
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8)
    c = SyntheticCorpus(cfg)
    b1 = c.batch(3)
    b2 = c.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the global batch
    s0 = c.batch(3, shard=0, num_shards=2)
    s1 = c.batch(3, shard=1, num_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])
    assert (b1["tokens"] < 97).all() and (b1["tokens"] >= 0).all()
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


# -------------------------------------------------------------- optimizer --
def test_adamw_reduces_quadratic():
    params = {"w": jnp.full((4,), 5.0, jnp.bfloat16)}
    oc = adamw.OptConfig(peak_lr=0.5, min_lr=0.05, warmup_steps=1,
                         total_steps=60, weight_decay=0.0)
    state = adamw.init_state(params)
    for _ in range(60):
        grads = {"w": state["master"]["w"] * 2.0}
        params, state, _, stats = adamw.apply_updates(oc, state, params,
                                                      grads)
    assert float(jnp.abs(state["master"]["w"]).max()) < 0.3
    assert stats["grad_norm"] >= 0


def test_error_feedback_compression_telescopes():
    g = {"w": jnp.asarray(np.linspace(-3, 7, 64), jnp.float32)}
    err = {"w": jnp.zeros(64, jnp.float32)}
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(30):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        total_true += np.asarray(gi["w"])
        deq, err = adamw.compress_decompress(gi, err)
        total_sent += np.asarray(deq["w"])
    # residual feedback keeps cumulative error bounded by one quantum
    assert np.max(np.abs(total_true - total_sent)) < 0.2


# -------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
             "nested": {"b": jnp.ones((3,), jnp.float32),
                        "step": jnp.asarray(7, jnp.int32)}}
    ck.save(5, state, blocking=True)
    step, restored = ck.restore(state)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(state["a"], np.float32))
    assert restored["nested"]["step"] == 7


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, state, blocking=True)
    assert ck.all_steps() == [3, 4]


# --------------------------------------------------------- fault tolerance --
def test_straggler_detection_and_eviction():
    pol = StragglerPolicy(threshold=2.0, strikes_to_evict=2)
    recs = []
    t = 0.0
    for step in range(10):
        for host, dt in ((0, 1.0), (1, 1.0), (2, 5.0)):   # host 2 is slow
            recs.append(HeartbeatRecord(host, step, t, dt))
        verdict = pol.observe(recs)
    assert verdict[0] == "ok" and verdict[1] == "ok"
    assert verdict[2] == "evict"


def test_restart_policy_backoff_exhausts():
    rp = RestartPolicy(max_restarts=3, backoff_base=0.5, backoff_cap=1.0)
    delays = [rp.next_delay() for _ in range(4)]
    assert delays[:3] == [0.5, 1.0, 1.0]
    assert delays[3] is None


def test_heartbeat_records():
    hb = Heartbeat(host_id=1)
    r1 = hb.beat(0)
    r2 = hb.beat(1)
    assert r2.step == 1 and r2.step_time >= 0


# ----------------------------------------------------------------- serving --
def test_dispersed_pool_matches_dense_reference():
    g = np.random.default_rng(0)
    cfg = PagePoolConfig(num_logical_pages=24, num_hot_pages=6,
                         page_shape=(4, 4), policy=policies.LRU)
    pool = DispersedKVPool(cfg)
    dense = np.zeros((24, 4, 4), np.float32)
    for _ in range(200):
        p = int(g.integers(0, 24))
        if g.random() < 0.5:
            val = g.standard_normal((4, 4)).astype(np.float32)
            pool.write(p, jnp.asarray(val))
            dense[p] = np.asarray(jnp.asarray(val, jnp.bfloat16),
                                  np.float32)
        else:
            got = np.asarray(pool.read(p), np.float32)
            np.testing.assert_array_equal(got, dense[p])
    final = np.asarray(pool.flush(), np.float32)
    np.testing.assert_array_equal(final, dense)


def test_pinned_pages_never_evicted():
    pool = DispersedKVPool(PagePoolConfig(
        num_logical_pages=16, num_hot_pages=4, page_shape=(2,),
        pin_first=1))
    pool.write(0, jnp.ones(2))
    for p in range(1, 16):
        pool.read(p)
    assert 0 in pool.tags                 # sink page stayed hot


def test_serve_engine_continuous_batching():
    from repro.configs import get
    cfg = get("phi3-mini-3.8b").reduced()
    from repro.models import get_model
    mdl = get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=48)
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=3)
            for i in range(5)]
    eng.run(reqs)
    for r in reqs:
        assert r.done and len(r.out) == 3


def test_serve_engine_ssm_state_slots():
    """Continuous batching over the SSM (falcon-mamba) state cache: per-slot
    recurrent state must not leak between requests."""
    from repro.configs import get
    from repro.models import get_model
    cfg = get("falcon-mamba-7b").reduced()
    mdl = get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(prompt=[2, 3, 4], max_new_tokens=3) for _ in range(4)]
    eng.run(reqs)
    for r in reqs:
        assert r.done and len(r.out) == 3
    # identical prompts + greedy decoding => identical outputs regardless of
    # which slot/order served them (state isolation)
    outs = {tuple(r.out) for r in reqs}
    assert len(outs) == 1, outs
