"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step and one decode step on CPU,
asserting output shapes and the absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import get_model

B, S = 2, 16


def _batch(cfg, s=S, decode=False):
    b = B
    sl = 1 if decode else s
    pos = (jnp.full((b, 1), 5, jnp.int32) if decode else
           jnp.broadcast_to(jnp.arange(sl)[None], (b, sl)).astype(jnp.int32))
    batch = {"tokens": jnp.full((b, sl), 3, jnp.int32), "positions": pos}
    if cfg.positional == "mrope":
        batch["positions3"] = jnp.broadcast_to(pos[None], (3, b, sl))
    if cfg.encoder_decoder and not decode:
        batch["audio_embeds"] = jnp.full(
            (b, cfg.encoder_seq, cfg.d_model), 0.01, jnp.float32)
    if cfg.frontend == "vision" and not decode:
        batch["vision_embeds"] = jnp.full((b, sl, cfg.d_model), 0.01)
        batch["vision_mask"] = jnp.zeros((b, sl), bool).at[:, :4].set(True)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_forward(name):
    cfg = ARCHS[name].reduced()
    mdl = get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0))
    logits, aux = mdl.train_logits(params, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert float(aux) >= 0.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = ARCHS[name].reduced()
    mdl = get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0))
    cache = mdl.init_cache(B, max_len=32)
    if cfg.encoder_decoder:
        cache["ek"] = jnp.full(cache["ek"].shape, 0.01, cache["ek"].dtype)
        cache["ev"] = jnp.full(cache["ev"].shape, 0.01, cache["ev"].dtype)
    logits, cache2 = mdl.decode_step(params, cache, _batch(cfg, decode=True))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    for k in cache:
        assert cache2[k].shape == cache[k].shape


@pytest.mark.parametrize("name", ["qwen3-8b", "falcon-mamba-7b",
                                  "recurrentgemma-2b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_decode_consistency(name):
    """Sequential decode through the cache must reproduce the full-sequence
    (prefill) logits — validates every cache/state update path (GQA ring,
    SSM recurrence, RG-LRU/window hybrid, MLA latent cache)."""
    import dataclasses
    cfg = dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
    if cfg.moe:
        # Capacity-based routing legitimately differs between full-sequence
        # and per-token dispatch (different group capacities); disable MoE so
        # this test isolates the MLA latent-cache path.
        cfg = dataclasses.replace(cfg, moe=False, num_experts=0,
                                  moe_top_k=0, first_dense_layers=0)
    mdl = get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(1))
    s = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens,
             "positions": jnp.arange(s, dtype=jnp.int32)[None]}
    full_logits, _ = mdl.train_logits(params, batch)

    cache = mdl.init_cache(1, max_len=max(s, cfg.sliding_window or s))
    outs = []
    for t in range(s):
        b = {"tokens": tokens[:, t:t + 1],
             "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = mdl.decode_step(params, cache, b)
        outs.append(np.asarray(logits[0, 0], np.float32))
    got = np.stack(outs)
    want = np.asarray(full_logits[0], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_reduced_param_counts_small():
    for name, cfg in ARCHS.items():
        r = cfg.reduced()
        assert r.param_count() < 30e6, name
