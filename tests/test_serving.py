"""Serving robustness tests: traffic determinism, pool invariants, the
admission/deadline/preemption control plane, and the seeded chaos
acceptance scenario (faulted run == fault-free run, token for token)."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import registry
from repro.core import policies
from repro.models import get_model
from repro.runtime.fault_tolerance import Heartbeat
from repro.serve import (DispersedKVPool, PagePoolConfig, Request,
                         ServeEngine, chaos, slo, traffic)

MAX_LEN = 48
PAGE = 8


@functools.lru_cache(maxsize=None)
def _built(arch="phi3-mini-3.8b"):
    cfg = registry.get(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    return cfg, model, params, decode


def _engine(**kw):
    cfg, model, params, decode = _built()
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    return ServeEngine(cfg, params, model=model, decode_fn=decode, **kw)


def _dispersed(**kw):
    kw.setdefault("kv_mode", "dispersed")
    kw.setdefault("page_size", PAGE)
    return _engine(**kw)


# ---------------------------------------------------------------------------
# Satellite: empty prompts are rejected, not decoded forever.
# ---------------------------------------------------------------------------


def test_empty_prompt_rejected():
    eng = _engine()
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(prompt=[]))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.enqueue(Request(prompt=[]))
    assert all(r is None for r in eng.active) and not eng.queue


# ---------------------------------------------------------------------------
# Satellite: DispersedKVPool invariants.
# ---------------------------------------------------------------------------


def _pool(hot=4, pages=16, policy=policies.FIFO, pin_first=1):
    return DispersedKVPool(PagePoolConfig(
        num_logical_pages=pages, num_hot_pages=hot, page_shape=(6,),
        policy=policy, pin_first=pin_first))


def test_read_after_spill_round_trip_bit_identical():
    pool = _pool(hot=4, pages=12)
    want = {p: jnp.full((6,), 1.0 + p * 0.125, jnp.bfloat16)
            for p in range(12)}
    for p, v in want.items():          # 12 pages through 4 hot slots
        pool.write(p, v)
    assert pool.spills > 0             # dirty victims really went cold
    for p, v in want.items():
        got = pool.read(p)
        assert jnp.array_equal(got, v), f"page {p} corrupted by spill"


@pytest.mark.parametrize("policy", sorted(policies.POLICY_NAMES),
                         ids=lambda p: policies.POLICY_NAMES[p])
def test_pinned_sink_never_evicted_any_policy(policy):
    pool = _pool(hot=4, pages=32, policy=policy, pin_first=1)
    pool.write(0, jnp.arange(6, dtype=jnp.bfloat16))
    rng = np.random.default_rng(0)
    for p in rng.integers(1, 32, 200):
        pool.read(int(p))
        assert 0 in pool.tags, (
            f"pinned sink evicted under {policies.POLICY_NAMES[policy]}")
    assert jnp.array_equal(pool.read(0), jnp.arange(6, dtype=jnp.bfloat16))


def test_flush_idempotent():
    pool = _pool(hot=4, pages=8)
    for p in range(6):
        pool.write(p, jnp.full((6,), float(p), jnp.bfloat16))
    cold1 = np.asarray(pool.flush().astype(jnp.float32))
    spills = pool.spills
    cold2 = np.asarray(pool.flush().astype(jnp.float32))
    assert np.array_equal(cold1, cold2)
    assert pool.spills == spills          # second flush moved nothing
    assert not pool.dirty.any()


def test_reset_stats_keeps_contents():
    pool = _pool()
    pool.write(3, jnp.ones((6,), jnp.bfloat16))
    pool.read(5)
    assert pool.misses > 0
    pool.reset_stats()
    st = pool.stats()
    assert (st["hits"], st["misses"], st["spills"], st["fills"]) == (0,) * 4
    assert jnp.array_equal(pool.read(3), jnp.ones((6,), jnp.bfloat16))


def test_shrink_spills_and_preserves_data():
    pool = _pool(hot=8, pages=16)
    want = {p: jnp.full((6,), 2.0 + p, jnp.bfloat16) for p in range(8)}
    for p, v in want.items():
        pool.write(p, v)
    pool.shrink(4)
    assert pool.cfg.num_hot_pages == 4
    assert pool.hot.shape[0] == 4
    assert pool.shrinks == 1
    for p, v in want.items():          # victims came back from cold intact
        assert jnp.array_equal(pool.read(p), v)
    with pytest.raises(ValueError):
        pool.shrink(2)                 # pinned + 2 evictable won't fit


# ---------------------------------------------------------------------------
# Traffic generator: seeded and replayable.
# ---------------------------------------------------------------------------


def test_scenario_deterministic_per_seed():
    cfg = traffic.TrafficConfig(arrival="mmpp", n_requests=12)
    a, b = traffic.generate(cfg, seed=7), traffic.generate(cfg, seed=7)
    assert a.arrivals == b.arrivals
    c = traffic.generate(cfg, seed=8)
    assert a.arrivals != c.arrivals
    ts = [s.t for s in a.arrivals]
    assert ts == sorted(ts) and all(len(s.prompt) >= 1 for s in a.arrivals)


def test_traffic_mixes_cover_tenant_families():
    scen = traffic.generate(
        dataclasses.replace(traffic.TRAFFIC_MIXES["steady"],
                            n_requests=64), seed=0)
    names = {s.tenant for s in scen.arrivals}
    assert "dense" in names and len(names) >= 3


# ---------------------------------------------------------------------------
# Control plane: admission, deadlines, preemption.
# ---------------------------------------------------------------------------


def test_bounded_queue_backpressure_rejects():
    eng = _engine(max_queue=3)
    reqs = [Request(prompt=[1 + i], max_new_tokens=2) for i in range(6)]
    accepted = [eng.enqueue(r) for r in reqs]
    assert accepted == [True] * 3 + [False] * 3
    assert eng.rejected == 3
    assert all(r.status == "rejected" for r in reqs[3:])


def test_deadline_timeout_retries_then_fails():
    eng = _engine(max_retries=2, backoff_base=1.0, backoff_cap=4.0)
    # deadline shorter than the prompt: every attempt must time out
    req = Request(prompt=[3] * 10, max_new_tokens=8, deadline=2.0,
                  arrival_t=0.0)
    eng.serve([req], max_steps=200)
    assert req.status == "failed"
    assert req.retries == 2
    assert eng.deadline_misses == 3        # initial attempt + two retries


def test_preempted_request_resumes_bit_identically():
    r_ref = Request(prompt=[5, 6, 7], max_new_tokens=8)
    _engine(slots=1).run([r_ref])
    assert r_ref.done

    for mk in (_engine, _dispersed):
        eng = mk(slots=1)
        req = Request(prompt=[5, 6, 7], max_new_tokens=8)
        assert eng.submit(req)
        for _ in range(5):                 # past prefill, mid-decode
            eng.step()
        assert len(req.out) > 0 and not req.done
        eng.preempt(0)
        assert req.status == "preempted" and eng.active[0] is None
        eng._admit_from_queue(eng.clock.now)
        assert req.status == "running"
        while not req.done:
            eng.step()
        assert req.out == r_ref.out, "resume diverged from the unpreempted run"
        assert req.preemptions == 1


def test_dispersed_mode_rejects_recurrent_state():
    cfg = registry.get("falcon-mamba-7b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent state"):
        ServeEngine(cfg, params, slots=2, max_len=MAX_LEN, model=model,
                    kv_mode="dispersed", page_size=PAGE)


def test_heartbeat_virtual_time_is_deterministic():
    hb = Heartbeat(host_id=3)
    rec = hb.beat(1, now=10.0, step_time=2.5)
    assert (rec.host, rec.step, rec.t, rec.step_time) == (3, 1, 10.0, 2.5)


# ---------------------------------------------------------------------------
# Acceptance: the seeded chaos scenario vs its fault-free twin.
# ---------------------------------------------------------------------------


def _chaos_scenario():
    cfg = dataclasses.replace(
        traffic.TRAFFIC_MIXES["steady"], n_requests=6, max_len=MAX_LEN,
        vocab=_built()[0].vocab_size, deadline=400.0)
    return traffic.generate(cfg, seed=1)


def test_chaos_run_bit_identical_to_fault_free():
    scen = _chaos_scenario()
    hot = 12

    e0 = _dispersed(hot_pages=hot)
    free = e0.serve(scen)
    assert all(r.status == "done" for r in free)

    profile = chaos.FAULT_PROFILES["chaos"](
        scen.horizon + 60, 2, hot, seed=0)
    kinds = {e.kind for e in profile.events}
    assert kinds == {"latency_spike", "slot_fail", "mem_pressure"}

    e1 = _dispersed(hot_pages=hot)
    inj = chaos.FaultInjector(profile)
    hit = e1.serve(scen, chaos=inj)
    assert {e.kind for e in inj.applied} == kinds   # every fault fired
    assert e1.pool.shrinks == 1                     # pool shrank live

    # all admitted requests complete under fire...
    assert all(r.status == "done" for r in hit)
    # ...with outputs bit-identical to the fault-free run — including any
    # preempted-and-resumed victims
    by_rid = {r.rid: r for r in free}
    for r in hit:
        assert r.out == by_rid[r.rid].out, (
            f"rid {r.rid} diverged under chaos (preemptions="
            f"{r.preemptions})")

    rep = slo.summarize(e1, hit)
    assert rep.n_done == len(hit)
    assert rep.degraded_ticks > 0
    assert rep.p99_decode_ticks >= rep.p50_decode_ticks > 0


# ---------------------------------------------------------------------------
# SweepResult.from_table / quantile and the SLO metric registry.
# ---------------------------------------------------------------------------


def test_from_table_pareto_and_metrics():
    rows = []
    for hot, (bytes_, p99, tps, miss) in {
            4: (4096, 3.0, 0.5, 0.2), 8: (8192, 1.5, 0.8, 0.0),
            16: (16384, 1.6, 0.9, 0.0)}.items():
        rows.append(dict(hot_pages=hot, policy=policies.FIFO,
                         hot_bytes=bytes_, p99_decode_ticks=p99,
                         tokens_per_tick=tps, deadline_miss_rate=miss,
                         degraded_tokens_per_tick=tps * 0.5))
    res = api.SweepResult.from_table(
        dict(hot_pages=(4, 8, 16), policy=(policies.FIFO,)), rows)
    assert res.shape == (3, 1)
    assert res.value("hot_bytes", hot_pages=8) == 8192

    front = res.pareto("hot_bytes", "p99_decode_ticks")
    assert [r["hot_pages"] for r in front] == [4, 8]   # 16 dominated
    assert front[0]["policy_name"] == "fifo"

    res = res.derive("slo_attainment").derive("goodput")
    assert res.value("slo_attainment", hot_pages=4) == pytest.approx(0.8)
    assert res.value("goodput", hot_pages=8) == pytest.approx(0.8)
    res = res.derive("degraded_throughput_ratio")
    assert res.value("degraded_throughput_ratio",
                     hot_pages=16) == pytest.approx(0.5)


def test_quantile_collapses_axis():
    rows = [dict(cap=c, seed=s, lat=float(10 * c + s))
            for c in (1, 2) for s in range(5)]
    res = api.SweepResult.from_table(
        dict(cap=(1, 2), seed=tuple(range(5))), rows)
    q = res.quantile(50, over="seed")
    assert [a.name for a in q.axes] == ["cap"]
    assert q.value("lat", cap=1) == pytest.approx(12.0)
    assert q.value("lat", cap=2) == pytest.approx(22.0)
    with pytest.raises(KeyError):
        res.quantile(50, over="nope")


# ---------------------------------------------------------------------------
# Full sweep (slow tier): the benchmark suite end to end.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serving_slo_suite_smoke():
    from benchmarks import serving_slo
    rows = serving_slo.main(max_events=120)
    assert rows and all("p99" in r for r in rows)
    extra = serving_slo.json_extra()
    assert extra["pareto"]["none"]["p99"], "empty Pareto front"
