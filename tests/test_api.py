"""The `repro.api` front door: declarative Sweeps, session-owned caches,
the (bucket, geometry) execution planner, and the deprecation shims.

The planner contract pinned here (in the spirit of
``tests/test_machine_grid.py``):

  1. *compile budget*: a 2-geometry x full-latency-grid sweep compiles the
     engine exactly once per (program-shape bucket, L1 geometry), and an
     identical re-run — even from a brand-new Session — compiles nothing;
  2. *bit-identity*: grid points equal standalone ``simulate_one`` runs at
     the matching ``MachineParams`` (spot-checked), and the whole
     ablation-style grid equals the legacy per-geometry ``sweep_grid``
     path, per-point ``fold_exact`` certificates included;
  3. *isolation*: two Sessions share no Python state, and the process
     default is resettable via the ``fresh_default_session`` fixture.
"""

import numpy as np
import pytest

from benchmarks import common
from repro import api, rvv
from repro.core import policies, simulator

# Unique L1 geometries (3-way, unlike every other suite) so the jit cache
# is provably cold for the compile-budget assertions, whatever ran first.
GEOS = (api.L1Geometry(sets=48, ways=3), api.L1Geometry(sets=96, ways=3))

SWEEP = api.Sweep(
    kernels=("dropout", "gemv"), capacity=(4, 8),
    mem_latency=(1, 5), uop_hit_cycles=(1, 2),
    l1_geometry=GEOS, kernel_params="reduced")


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------


def test_unknown_kernel_raises_with_menu():
    with pytest.raises(KeyError, match="unknown kernel 'nope'.*dropout"):
        rvv.BENCHMARKS["nope"]
    with pytest.raises(KeyError, match="available: conv2d_7x7"):
        rvv.get_benchmark("nope")
    with pytest.raises(KeyError, match="unknown kernel"):
        api.Session().run(api.Sweep(kernels=["nope"]))


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="registered twice"):
        rvv.register_benchmark(
            "gemv", domain="x", paper_params={}, reduced_params={},
            scalar_cost=lambda **kw: None)(lambda **kw: None)


# ---------------------------------------------------------------------------
# Session isolation.
# ---------------------------------------------------------------------------


def test_sessions_share_nothing():
    s1, s2 = api.Session(), api.Session()
    b1 = s1.built("dropout", params="reduced")
    b2 = s2.built("dropout", params="reduced")
    assert b1 is not b2                      # independent build caches
    assert s1.built("dropout", params="reduced") is b1   # but each caches
    p1 = s1.prepared("dropout", params="reduced")
    assert s1.prepared("dropout", params="reduced") is p1
    assert not s2._prepared                  # s2 never prepared anything
    s1.reset()
    assert not s1._built and not s1._prepared
    assert s2.built("dropout", params="reduced") is b2   # s2 unaffected


def test_default_session_resettable(fresh_default_session):
    ses = fresh_default_session
    assert api.default_session() is ses
    assert not ses._built
    b = ses.built("dropout", params="reduced")
    assert ses._built
    fresh = api.reset_default_session()
    assert api.default_session() is fresh and fresh is not ses
    assert not fresh._built
    assert ses.built("dropout", params="reduced") is b   # old one intact


# ---------------------------------------------------------------------------
# The execution planner: compile budget + bit-identity + certificates.
# ---------------------------------------------------------------------------


def test_planner_compile_budget():
    ses = api.Session(refine=False)
    res = ses.run(SWEEP)
    preps = {(n, geo): ses.prepared(n, machine=SWEEP.machine_sweep(geo),
                                    params="reduced")
             for n in SWEEP.kernels for geo in GEOS}
    expected = {(geo, simulator._bucket(p.num_rows))
                for (n, geo), p in preps.items()}
    assert ses.compile_count() == len(expected), (
        "the planner must compile exactly once per (shape bucket, L1 "
        "geometry) — latency values and capacities are traced axes")
    assert res.meta["compiles"] == len(expected)
    assert len(res.meta["plan"]) == len(expected)

    # An identical sweep — even from a brand-new Session — reuses every
    # executable (the jit cache is keyed on shapes + static geometry only).
    res2 = ses.run(SWEEP)
    assert ses.compile_count() == len(expected)
    fresh = api.Session(refine=False)
    res3 = fresh.run(SWEEP)
    assert fresh.compile_count() == 0
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(res[k], res2[k])
        np.testing.assert_array_equal(res[k], res3[k])


def test_planner_bit_identity_spot_checks():
    ses = api.Session(refine=False)
    res = ses.run(SWEEP)
    assert res.shape == (2, 2, 1, 1, 2, 2, 1, 2)
    points = [
        dict(kernel="dropout", capacity=4, mem_latency=1, uop_hit_cycles=1,
             l1_geometry=GEOS[0]),
        dict(kernel="gemv", capacity=8, mem_latency=5, uop_hit_cycles=2,
             l1_geometry=GEOS[1]),
        dict(kernel="gemv", capacity=4, mem_latency=5, uop_hit_cycles=1,
             l1_geometry=GEOS[0]),
    ]
    for pt in points:
        geo = pt["l1_geometry"]
        machine = simulator.MachineParams(
            l1_sets=geo.sets, l1_ways=geo.ways,
            mem_latency=pt["mem_latency"],
            uop_hit_cycles=pt["uop_hit_cycles"])
        one = simulator.simulate_one(
            ses.built(pt["kernel"], params="reduced").program,
            pt["capacity"], machine=machine, fold=True)
        for k in simulator.COUNTER_NAMES:
            assert res.value(k, **pt) == one[k], (k, pt)
        # the fold-exactness certificate survives the planner per point
        # (simulate_one omits the key when the trace has no folds at all)
        assert res.value("fold_exact", **pt) == bool(
            one.get("fold_exact", True))


def test_geometry_axis_reproduces_ablation_grid():
    """The acceptance pin: one Session.run with a 2-point l1_geometry axis
    equals the legacy per-geometry sweep_grid path of the ablation suite,
    bit-identical on every counter, fold_exact flags preserved."""
    from benchmarks import ablation_sensitivity as ablation
    ses = api.Session()
    sweep = api.Sweep(kernels=ablation.APPS, capacity=(8, 32),
                      mem_latency=ablation.MEM_LATENCIES,
                      l1_geometry=ablation.GEOMETRIES, max_events=6_000)
    res = ses.run(sweep)
    cfg = simulator.SweepConfig.make([8, 32])
    for l1_kb in ablation.L1_KBYTES:
        geo = api.L1Geometry.from_kbytes(l1_kb)
        machines = ablation.machine_grid(l1_kb)
        legacy = ses.grid(ablation.APPS, cfg, machine=machines,
                          max_events=6_000)
        got = res.to_grid(l1_geometry=geo)
        for k in legacy:
            np.testing.assert_array_equal(
                got[k], legacy[k], err_msg=f"{k} at l1={l1_kb}k")
    assert bool(res["fold_exact"].all())     # truncated runs never fold


# ---------------------------------------------------------------------------
# SweepResult accessors.
# ---------------------------------------------------------------------------


def test_sweep_result_accessors():
    ses = api.Session(refine=False)
    res = ses.run(SWEEP)
    rows = res.to_rows()
    assert len(rows) == np.prod(res.shape) == res.meta["points"]
    r0 = rows[0]
    assert r0["kernel"] == "dropout" and r0["policy_name"] == "fifo"
    assert r0["l1_sets"] == 48 and r0["l1_ways"] == 3
    assert isinstance(r0["cycles"], int)
    sub = res.select(kernel="gemv", mem_latency=[1, 5])
    assert sub.shape == (1, 2, 1, 1, 2, 2, 1, 2)
    assert res.select(policy="fifo").shape == res.shape
    assert res.select(capacity=(4, 8)).shape == res.shape  # tuple == multi
    # ... except on the geometry axis, where a tuple is one (sets, ways)
    assert res.select(l1_geometry=(48, 3)).shape[4] == 1
    np.testing.assert_array_equal(
        res.array("cycles", kernel="gemv", l1_geometry=GEOS[0]),
        res["cycles"][1, :, 0, 0, 0].squeeze())
    with pytest.raises(KeyError, match="unknown axis"):
        res.select(not_an_axis=3)
    with pytest.raises(ValueError, match="no point"):
        res.select(capacity=99)
    with pytest.raises(ValueError, match="pin every"):
        res.value("cycles", kernel="gemv")
    with pytest.raises(ValueError, match="single L1 geometry"):
        res.to_grid()


def test_config_points_zipped_axis():
    pts = [api.ConfigPoint(4, policies.FIFO),
           api.ConfigPoint(4, policies.LRU),
           (4, policies.FIFO, True),
           dict(capacity=8, policy="opt")]
    ses = api.Session(refine=False)
    res = ses.run(api.Sweep(kernels=["dropout"], config_points=pts,
                            kernel_params="reduced"))
    assert [a.name for a in res.axes][1] == "config"
    assert res.shape[1] == 4
    assert res.select(capacity=4).shape[1] == 3
    assert res.select(policy="lru").shape[1] == 1
    assert res.select(capacity=[4, 8]).shape[1] == 4     # field multi-select
    assert res.select(policy=["fifo", "lru"]).shape[1] == 3
    v = res.value("cycles", capacity=4, policy=policies.FIFO,
                  alloc_no_fetch=True)
    assert isinstance(v, int)
    row = res.select(policy="opt").to_rows()[0]
    assert row["capacity"] == 8 and row["policy_name"] == "opt"


# ---------------------------------------------------------------------------
# Deprecation shims.
# ---------------------------------------------------------------------------


def test_deprecated_simulate_sweep():
    prog = api.Session().built("dropout", params="reduced").program
    cfg = simulator.SweepConfig.make([4, 32])
    with pytest.warns(DeprecationWarning, match="simulate_sweep"):
        old = simulator.simulate_sweep(prog, cfg)
    new = api.sweep_program(prog, cfg)
    assert old.keys() == new.keys()
    for k in old:
        np.testing.assert_array_equal(old[k], new[k], err_msg=k)


def test_deprecated_prepared_for_max_events(fresh_default_session):
    with pytest.warns(DeprecationWarning, match="max_events"):
        prep = common.prepared_for("dropout", max_events=500)
    # delegates into the default session's cache ...
    assert prep is fresh_default_session.prepared("dropout", max_events=500)
    # ... and matches the old direct-prepare path bit for bit.
    legacy = simulator.prepare(
        common.built("dropout").program, fold=False, max_events=500)
    assert prep.num_rows == legacy.num_rows
    assert prep.event_scale == legacy.event_scale
    np.testing.assert_array_equal(prep.ev.cost, legacy.ev.cost)
    np.testing.assert_array_equal(prep.weight, legacy.weight)
