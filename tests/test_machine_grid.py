"""Traced machine-parameter sweep axes: the (P, C, M) grid contract.

The engine promotes ``l1_hit_cycles`` / ``uop_hit_cycles`` / ``mem_latency``
from static jit arguments to traced sweep axes.  These tests pin the three
properties that make that safe and worthwhile:

  1. *bit-identity*: a machine grid point equals a standalone run at that
     machine's ``MachineParams`` (the classic one-point path),
  2. *one compile per program-shape bucket*: changing machine latency
     VALUES never recompiles — only shapes and the static L1 geometry do,
  3. *analytic conformance*: non-timing counters are machine-invariant and
     cycles are affine in the latencies (``costmodel.check_machine_affine``).
"""

import numpy as np
import pytest

from benchmarks import ablation_sensitivity as ablation
from benchmarks import common
from repro import rvv
from repro.core import costmodel, policies, simulator

# One machine-axis shape (M = 6) shared by every test below: machine VALUES
# are traced, so all (C = 2)-config grids here reuse a single executable.
MACHINES = simulator.MachineSweep.product(
    (1, 3, 10), uop_hit_cycles=(1, 2))

_PREPS = {}


def _prep(name="densenet121_l105"):
    if name not in _PREPS:
        b = rvv.BENCHMARKS[name]
        _PREPS[name] = simulator.prepare(b.build(**b.reduced_params).program)
    return _PREPS[name]


def test_machine_grid_matches_per_point_runs():
    sweep = simulator.SweepConfig.make([3, 8], policies.LRU)
    prep = _prep()
    grid = simulator.simulate_grid([prep], sweep, MACHINES)
    assert grid["cycles"].shape == (1, 2, len(MACHINES))
    for m in range(len(MACHINES)):
        ref = simulator.simulate_grid([prep], sweep, MACHINES.point(m))
        for k in simulator.COUNTER_NAMES:
            np.testing.assert_array_equal(grid[k][:, :, m], ref[k],
                                          err_msg=f"{k} at machine {m}")


def test_machine_values_never_recompile():
    sweep = simulator.SweepConfig.make([4, 6])
    prep = _prep()
    a = simulator.MachineSweep.make((1, 5, 9, 2, 7, 31), uop_hit_cycles=3)
    simulator.simulate_grid([prep], sweep, a)          # warm the bucket
    c0 = simulator.compile_count()
    b = simulator.MachineSweep.make((4, 8, 15, 16, 23, 42), l1_hit_cycles=1)
    simulator.simulate_grid([prep], sweep, b)
    assert simulator.compile_count() == c0, (
        "a machine-latency value change retraced the engine — the latency "
        "axes must be traced, not static")


def test_l1_geometry_stays_static():
    with pytest.raises(ValueError, match="static"):
        simulator.MachineSweep.from_params([
            simulator.MachineParams(l1_sets=64),
            simulator.MachineParams(l1_sets=256)])


def test_machine_affine_cross_check():
    sweep = simulator.SweepConfig.make([8, 32])
    out = simulator.simulate_grid([_prep()], sweep, MACHINES)
    coeffs = costmodel.check_machine_affine(out, MACHINES)
    # The cap-32 full VRF never spills/fills: its uop-latency slope is 0
    # and its mem slope still covers the kernel's own data misses.
    assert coeffs["cycles"][0, 1, 2] == 0          # uop_hit coefficient
    assert coeffs["cycles"][0, 1, 3] >= 1          # mem_latency coefficient


def test_scalar_cost_over_machine_sweep():
    c = simulator.ScalarCost(flop_ops=10, unique_lines=4)
    got = c.cycles(simulator.MachineSweep.make((1, 5)))
    np.testing.assert_array_equal(got, [24, 40])
    assert c.cycles(simulator.MachineParams(mem_latency=5)) == 40


# ---------------------------------------------------------------------------
# The ablation suite: full machine grid in one dispatch per L1 geometry.
# ---------------------------------------------------------------------------


def test_ablation_grid_single_dispatch():
    """The ablation machine grid must add zero compiles once its shape
    buckets are warm — the whole latency grid rides the traced axes.  (The
    per-point bit-identity of its counters is pinned above on a reduced
    kernel and exhaustively at paper size in the slow tier below.)"""
    rows = ablation.run(max_events=6_000)
    assert len(rows) == (len(ablation.APPS) * len(ablation.MEM_LATENCIES)
                         * len(ablation.L1_KBYTES))
    c0 = simulator.compile_count()
    ablation.run(max_events=6_000)                 # identical shapes: cached
    assert simulator.compile_count() == c0


@pytest.mark.slow
def test_ablation_grid_bit_identity_paper_size():
    """Exhaustive version of the above: the paper-size ablation grid equals
    per-machine runs on every (program, capacity, machine) point."""
    sweep = simulator.SweepConfig.make([8, 32])
    for l1_kb in ablation.L1_KBYTES:
        machines = ablation.machine_grid(l1_kb)
        grid = common.sweep_grid(ablation.APPS, sweep, machine=machines)
        costmodel.check_machine_affine(grid, machines)
        for mi in range(len(machines)):
            per = common.sweep_grid(ablation.APPS, sweep,
                                    machine=machines.point(mi))
            for k in simulator.COUNTER_NAMES:
                np.testing.assert_array_equal(
                    grid[k][:, :, mi], per[k],
                    err_msg=f"{k} l1={l1_kb}k machine {mi}")
