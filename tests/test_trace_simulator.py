"""Unit tests: trace eDSL, event expansion, cycle simulator mechanics."""

import numpy as np
import pytest

from repro.core import events, isa, policies, simulator
from repro.core.trace import Assembler, MemoryMap


def _prog(body):
    mm = MemoryMap()
    a_buf = mm.alloc("a", np.arange(64, dtype=np.float32))
    a = Assembler("t")
    body(a, a_buf)
    return a.finalize(mm)


def test_repeat_expansion_strides():
    def body(a, buf):
        with a.repeat(4):
            a.vle(1, buf, stride=32)
            a.vadd(2, 1, 1)
            a.vse(2, buf + 128, stride=32)
    p = _prog(body)
    assert p.num_instructions == 12
    vle_addrs = p.addr[p.op == isa.VLE]
    assert list(vle_addrs) == [0, 32, 64, 96]
    vse_addrs = p.addr[p.op == isa.VSE]
    assert list(vse_addrs) == [128, 160, 192, 224]


def test_nested_repeat_two_level_strides():
    def body(a, buf):
        with a.repeat(3):                       # outer: stride2
            with a.repeat(2):                   # inner: stride
                a.vle(1, buf, stride=4, stride2=100)
                a.vmacc(2, 1, 1)
    p = _prog(body)
    addrs = list(p.addr[p.op == isa.VLE])
    assert addrs == [0, 4, 100, 104, 200, 204]


def test_event_expansion_vmacc_three_operands():
    def body(a, buf):
        a.vmacc(3, 1, 2)
    p = _prog(body)
    ev = events.expand(p)
    regs = list(ev.reg[ev.reg_valid])      # REG lanes in vs1, vs2, vd order
    assert regs == [1, 2, 3]
    # vd of vmacc must be fetched (destination-is-source, paper 3.2.1)
    assert bool(ev.vd_reads[0])
    # vs2's tag check locks vs1; vd's locks both (serial check, §3.2.1)
    assert ev.lock_vs1[0] == 1 and ev.lock_vs2[0] == 2


def test_mask_register_never_in_events():
    def body(a, buf):
        a.vmslt(1, 2)          # writes v0
        a.vmerge(3, 1, 2)      # reads v0 implicitly
    p = _prog(body)
    ev = events.expand(p)
    assert (ev.reg[ev.reg_valid] != isa.MASK_REG).all()
    assert isa.MASK_REG in p.active_vregs()


def test_next_use_vectorised_matches_naive():
    rng = np.random.default_rng(7)
    for _ in range(25):
        T = int(rng.integers(1, 200))
        reg = rng.integers(0, 12, size=(T, 3)).astype(np.int8)
        valid = rng.random((T, 3)) < 0.7
        fast = events._next_use(reg, valid)
        slow = events._next_use_naive(reg, valid)
        np.testing.assert_array_equal(fast, slow)


def test_repeat_records_periodicity_metadata():
    def body(a, buf):
        with a.repeat(3):                   # outer
            with a.repeat(4):               # inner, replicated 3x
                a.vadd(1, 2, 3)
            a.vadd(2, 1, 1)
    p = _prog(body)
    # inner block (len 1, count 4) recorded at each outer replica + outer.
    assert (0, 5, 3) in p.repeats
    inner = [s for s in p.repeats if s[2] == 4]
    assert [s[0] for s in inner] == [0, 5, 10]
    assert all(s[1] == 1 for s in inner)


def test_full_vrf_never_misses():
    def body(a, buf):
        for r in range(1, 31):
            a.vadd(r, max(r - 1, 1), max(r - 2, 1))
    p = _prog(body)
    out = simulator.simulate_one(p, 32)
    assert out["vrf_misses"] == 0
    assert out["stall_cycles"] == 0


def test_compulsory_misses_only_when_capacity_sufficient():
    def body(a, buf):
        with a.repeat(10):
            a.vle(1, buf)
            a.vle(2, buf + 32)
            a.vadd(3, 1, 2)
            a.vse(3, buf + 64)
    p = _prog(body)
    out = simulator.simulate_one(p, 4)
    assert out["vrf_misses"] == 3          # v1, v2, v3 cold misses
    assert out["spills"] == 0


def test_fifo_thrash_below_working_set():
    # Working set of 4 regs cycled; capacity 3 + FIFO => every access misses.
    def body(a, buf):
        with a.repeat(8):
            a.vadd(1, 2, 3)
            a.vadd(2, 3, 4)
            a.vadd(3, 4, 1)
            a.vadd(4, 1, 2)
    p = _prog(body)
    o3 = simulator.simulate_one(p, 3)
    o5 = simulator.simulate_one(p, 5)
    assert o3["hit_rate"] < 0.5
    assert o5["hit_rate"] > 0.85
    assert o3["cycles"] > o5["cycles"]


def test_dirty_eviction_spills():
    def body(a, buf):
        for r in range(1, 8):
            a.vle(r, buf)                  # writes regs 1..7 (dirty)
        a.vle(1, buf)
    p = _prog(body)
    out = simulator.simulate_one(p, 3)
    assert out["spills"] > 0


def test_operand_locking_prevents_inflight_eviction():
    # vmacc(3,1,2) with capacity 3: installing vd=3 must not evict vs1/vs2.
    def body(a, buf):
        a.vle(1, buf)
        a.vle(2, buf + 32)
        a.vmacc(3, 1, 2)
    p = _prog(body)
    out = simulator.simulate_one(p, 3)
    # exactly 3 compulsory misses; no re-fetch of v1/v2 within vmacc
    assert out["vrf_misses"] == 3


def test_scalar_cost_model():
    c = simulator.ScalarCost(flop_ops=100, int_ops=50, loads=10, stores=5,
                             unique_lines=2, loop_iters=10)
    # 100*2 + 50 + 10*1.5 + 5 + 2*5 + 10*3
    assert c.cycles() == 310
