"""Shared fixtures: process-default `repro.api` Session management.

The default Session is deliberately long-lived (suites share its built /
prepared caches), so tests that need isolation opt into
``fresh_default_session`` instead of the whole suite paying a cache reset.
"""

import pytest

from repro import api


@pytest.fixture
def fresh_default_session():
    """A fresh process-default Session for one test; the previous default
    (and every cache it holds) is restored afterwards."""
    old = api._DEFAULT_SESSION
    ses = api.reset_default_session()
    yield ses
    api._DEFAULT_SESSION = old
