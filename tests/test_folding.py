"""Periodic folding: exactness on steady-state traces, honesty elsewhere.

``fold=True`` simulates warm-up + two measured periods of each repeat block
and extrapolates counters algebraically.  For steady-state kernels the
result is *bit-identical* to simulating the whole trace; the engine's
``fold_exact`` flag (measured period A == measured period B) must certify
exactly that.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except Exception:                                     # pragma: no cover
    HAVE_HYP = False

from repro.core import folding, isa, simulator
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import dropout, gemv, jacobi2d, somier


def _stream_program(iters=2048):
    """Unit-stride streaming loop (steady after the L1 warm-up)."""
    mm = MemoryMap()
    src = mm.alloc("src", iters * isa.VL_ELEMS)
    dst = mm.alloc("dst", iters * isa.VL_ELEMS)
    a = Assembler("stream")
    with a.repeat(iters):
        a.vle(1, src, stride=32)
        a.vmul_sc(2, 1, 3.0)
        a.vse(2, dst, stride=32)
        a.scalar(2)
    return a.finalize(mm)


def _assert_fold_exact(program, caps=(3, 8, 32)):
    sweep = simulator.SweepConfig.make(list(caps))
    full = simulator.simulate_sweep(program, sweep)
    fold = simulator.simulate_sweep(program, sweep, fold=True)
    assert fold["fold_exact"].all()
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_plan_shrinks_streaming_trace():
    p = _stream_program()
    plan = folding.plan(p)
    assert plan is not None and plan.num_folds == 1
    assert len(plan.rows) < 0.4 * p.num_instructions


def test_fold_exact_streaming():
    _assert_fold_exact(_stream_program())


def test_fold_exact_dropout():
    # Steady-state kernel #1 (paper size): exact at every capacity.
    p = dropout.build(**dropout.PAPER).program
    _assert_fold_exact(p)


@pytest.mark.slow
def test_fold_exact_gemv_paper():
    # Steady-state kernel #2 (paper size): exact at every capacity.
    p = gemv.build(**gemv.PAPER).program
    _assert_fold_exact(p)


def test_fold_flag_honest_on_non_steady_trace():
    """A loop whose second half touches different data is not steady: the
    fold must either not trigger or flag itself as inexact."""
    mm = MemoryMap()
    buf = mm.alloc("buf", 4096)
    a = Assembler("phase_change")
    with a.repeat(64):
        a.vle(1, buf, stride=32)
        a.vse(1, buf + 8192, stride=96)
    p = a.finalize(mm)
    sweep = simulator.SweepConfig.make([4])
    fold = simulator.simulate_sweep(p, sweep, fold=True)
    full = simulator.simulate_sweep(p, sweep)
    if "fold_exact" in fold and fold["fold_exact"].all():
        for k in simulator.COUNTER_NAMES:
            np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_weight_algebra():
    """Total weights must cover every dropped iteration exactly once."""
    p = _stream_program()
    plan = folding.plan(p)
    assert int(plan.weight.sum()) == p.num_instructions
    assert int(plan.wa.sum()) == int(plan.wb.sum()) > 0


# ---------------------------------------------------------------------------
# Property test: fold_exact => extrapolation exact, across traced machines.
# ---------------------------------------------------------------------------


def _random_repeat_program(rng: np.random.Generator):
    """A random (foldable-shaped) repeat program: 1-3 streams with random
    strides and ops, a random working set, random iteration count."""
    mm = MemoryMap()
    n_streams = int(rng.integers(1, 4))
    iters = int(rng.integers(64, 512))
    bufs = [mm.alloc(f"s{i}", iters * isa.VL_ELEMS + 64)
            for i in range(n_streams)]
    a = Assembler("rand_repeat")
    with a.repeat(iters):
        for i, buf in enumerate(bufs):
            stride = int(rng.choice([4, 32, 64]))
            reg = 1 + i
            a.vle(reg, buf, stride=stride)
            if rng.random() < 0.5:
                a.vmacc(reg + n_streams, reg, reg)
            else:
                a.vmul_sc(reg + n_streams, reg, 1.5)
        a.vse(1 + n_streams, bufs[0] + 32, stride=32)
    return a.finalize(mm)


def _random_machines(rng: np.random.Generator) -> simulator.MachineSweep:
    m = 3      # fixed M: machine VALUES vary per seed, shapes stay cached
    return simulator.MachineSweep(
        l1_hit_cycles=rng.integers(0, 3, m).astype(np.int32),
        uop_hit_cycles=rng.integers(1, 4, m).astype(np.int32),
        mem_latency=rng.integers(1, 12, m).astype(np.int32))


def _check_fold_exact_implies_equal(program, machines):
    """The property: wherever the engine certifies ``fold_exact``, the
    algebraically extrapolated counters equal the full unfolded simulation
    — independently at every (capacity, machine) grid point."""
    sweep = simulator.SweepConfig.make([3, 8])
    fold = simulator.simulate_sweep(program, sweep, machines, fold=True)
    if "fold_exact" not in fold:
        return                                    # nothing folded: vacuous
    full = simulator.simulate_sweep(program, sweep, machines)
    exact = fold["fold_exact"]
    assert exact.shape == full["cycles"].shape
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(
            fold[k][exact], full[k][exact],
            err_msg=f"{k}: certified-exact fold diverged from full run")


# The deterministic seed pins run regardless of hypothesis availability:
# seed 4 is the draw that exposed the non-stationary-reuse certification
# hole, and a random strategy would almost never resample it.  The wider
# sweep rides the slow tier; with hypothesis installed an extra randomized
# search runs on top.
@pytest.mark.parametrize("seed", (0, 2, 4))
def test_fold_exact_property_random_programs(seed):
    rng = np.random.default_rng(seed)
    _check_fold_exact_implies_equal(
        _random_repeat_program(rng), _random_machines(rng))


@pytest.mark.slow
@pytest.mark.parametrize("seed", (1, 3, *range(5, 30)))
def test_fold_exact_property_random_programs_exhaustive(seed):
    rng = np.random.default_rng(seed)
    _check_fold_exact_implies_equal(
        _random_repeat_program(rng), _random_machines(rng))


if HAVE_HYP:                                          # pragma: no cover
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_fold_exact_property_hypothesis(seed):
        rng = np.random.default_rng(seed)
        _check_fold_exact_implies_equal(
            _random_repeat_program(rng), _random_machines(rng))


# ---------------------------------------------------------------------------
# Regression pin: fold_exact truth per kernel must not silently flip.
# ---------------------------------------------------------------------------

# Paper-size certification status (at capacity 8, the paper's design point).
# dropout/gemv stream steadily and certify exact; jacobi2d's ping-pong
# steps and somier's force phases defeat the period detector, so their
# folds must stay HONESTLY flagged inexact until a state-snapshot pass
# (ROADMAP) makes them exact — a folding change that flips any of these
# silently is a certification bug.
FOLD_EXACT_TRUTH = {
    dropout: True,
    gemv: True,
    jacobi2d: False,
    somier: False,
}


@pytest.mark.parametrize("mod", sorted(FOLD_EXACT_TRUTH, key=lambda m:
                                       m.__name__))
def test_fold_exact_certification_pinned(mod):
    from benchmarks import common    # shares paper-size builds + fold plans
    name = mod.__name__.rsplit(".", 1)[-1]
    prep = common.prepared_for(name, fold=True)
    out = simulator.simulate_grid([prep], simulator.SweepConfig.make([8]))
    assert "fold_exact" in out, f"{name} no longer folds at all"
    assert bool(out["fold_exact"].all()) is FOLD_EXACT_TRUTH[mod], (
        f"{name}: fold_exact certification flipped")
