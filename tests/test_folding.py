"""Periodic folding: exactness on steady-state traces, honesty elsewhere.

``fold=True`` simulates warm-up + two measured periods of each repeat block
and extrapolates counters algebraically.  For steady-state kernels the
result is *bit-identical* to simulating the whole trace; the engine's
``fold_exact`` flag (measured period A == measured period B) must certify
exactly that.
"""

import numpy as np
import pytest

from repro.core import folding, isa, simulator
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import dropout, gemv


def _stream_program(iters=2048):
    """Unit-stride streaming loop (steady after the L1 warm-up)."""
    mm = MemoryMap()
    src = mm.alloc("src", iters * isa.VL_ELEMS)
    dst = mm.alloc("dst", iters * isa.VL_ELEMS)
    a = Assembler("stream")
    with a.repeat(iters):
        a.vle(1, src, stride=32)
        a.vmul_sc(2, 1, 3.0)
        a.vse(2, dst, stride=32)
        a.scalar(2)
    return a.finalize(mm)


def _assert_fold_exact(program, caps=(3, 8, 32)):
    sweep = simulator.SweepConfig.make(list(caps))
    full = simulator.simulate_sweep(program, sweep)
    fold = simulator.simulate_sweep(program, sweep, fold=True)
    assert fold["fold_exact"].all()
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_plan_shrinks_streaming_trace():
    p = _stream_program()
    plan = folding.plan(p)
    assert plan is not None and plan.num_folds == 1
    assert len(plan.rows) < 0.4 * p.num_instructions


def test_fold_exact_streaming():
    _assert_fold_exact(_stream_program())


def test_fold_exact_dropout():
    # Steady-state kernel #1 (paper size): exact at every capacity.
    p = dropout.build(**dropout.PAPER).program
    _assert_fold_exact(p)


@pytest.mark.slow
def test_fold_exact_gemv_paper():
    # Steady-state kernel #2 (paper size): exact at every capacity.
    p = gemv.build(**gemv.PAPER).program
    _assert_fold_exact(p)


def test_fold_flag_honest_on_non_steady_trace():
    """A loop whose second half touches different data is not steady: the
    fold must either not trigger or flag itself as inexact."""
    mm = MemoryMap()
    buf = mm.alloc("buf", 4096)
    a = Assembler("phase_change")
    with a.repeat(64):
        a.vle(1, buf, stride=32)
        a.vse(1, buf + 8192, stride=96)
    p = a.finalize(mm)
    sweep = simulator.SweepConfig.make([4])
    fold = simulator.simulate_sweep(p, sweep, fold=True)
    full = simulator.simulate_sweep(p, sweep)
    if "fold_exact" in fold and fold["fold_exact"].all():
        for k in simulator.COUNTER_NAMES:
            np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_weight_algebra():
    """Total weights must cover every dropped iteration exactly once."""
    p = _stream_program()
    plan = folding.plan(p)
    assert int(plan.weight.sum()) == p.num_instructions
    assert int(plan.wa.sum()) == int(plan.wb.sum()) > 0
