"""Periodic folding: exactness on steady-state traces, honesty elsewhere.

``fold=True`` simulates warm-up + two measured periods of each repeat block
and extrapolates counters algebraically.  For steady-state kernels the
result is *bit-identical* to simulating the whole trace; the engine's
``fold_exact`` flag (measured period A == measured period B) must certify
exactly that.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except Exception:                                     # pragma: no cover
    HAVE_HYP = False

from repro import api
from repro.core import folding, isa, simulator
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import conv2d_batched, dropout, gemv, jacobi2d, mha, somier


def _stream_program(iters=2048):
    """Unit-stride streaming loop (steady after the L1 warm-up)."""
    mm = MemoryMap()
    src = mm.alloc("src", iters * isa.VL_ELEMS)
    dst = mm.alloc("dst", iters * isa.VL_ELEMS)
    a = Assembler("stream")
    with a.repeat(iters):
        a.vle(1, src, stride=32)
        a.vmul_sc(2, 1, 3.0)
        a.vse(2, dst, stride=32)
        a.scalar(2)
    return a.finalize(mm)


def _assert_fold_exact(program, caps=(3, 8, 32),
                       machine=simulator.DEFAULT_MACHINE):
    sweep = simulator.SweepConfig.make(list(caps))
    full = api.sweep_program(program, sweep, machine)
    fold = api.sweep_program(program, sweep, machine, fold=True)
    assert fold["fold_exact"].all()
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_plan_shrinks_streaming_trace():
    p = _stream_program()
    plan = folding.plan(p)
    assert plan is not None and plan.num_folds == 1
    assert len(plan.rows) < 0.4 * p.num_instructions


def test_fold_exact_streaming():
    _assert_fold_exact(_stream_program())


def test_fold_exact_dropout():
    # Steady-state kernel #1 (paper size): exact at every capacity.
    p = dropout.build(**dropout.PAPER).program
    _assert_fold_exact(p)


@pytest.mark.slow
def test_fold_exact_gemv_paper():
    # Steady-state kernel #2 (paper size): exact at every capacity.
    p = gemv.build(**gemv.PAPER).program
    _assert_fold_exact(p)


def test_fold_flag_honest_on_non_steady_trace():
    """A loop whose second half touches different data is not steady: the
    fold must either not trigger or flag itself as inexact."""
    mm = MemoryMap()
    buf = mm.alloc("buf", 4096)
    a = Assembler("phase_change")
    with a.repeat(64):
        a.vle(1, buf, stride=32)
        a.vse(1, buf + 8192, stride=96)
    p = a.finalize(mm)
    sweep = simulator.SweepConfig.make([4])
    fold = api.sweep_program(p, sweep, fold=True)
    full = api.sweep_program(p, sweep)
    if "fold_exact" in fold and fold["fold_exact"].all():
        for k in simulator.COUNTER_NAMES:
            np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


def test_fold_weight_algebra():
    """Total weights must cover every dropped iteration exactly once."""
    p = _stream_program()
    plan = folding.plan(p)
    assert int(plan.weight.sum()) == p.num_instructions
    assert int(plan.wa.sum()) == int(plan.wb.sum()) > 0


# ---------------------------------------------------------------------------
# Property test: fold_exact => extrapolation exact, across traced machines.
# ---------------------------------------------------------------------------


def _random_repeat_program(rng: np.random.Generator):
    """A random (foldable-shaped) repeat program: 1-3 streams with random
    strides and ops, a random working set, random iteration count."""
    mm = MemoryMap()
    n_streams = int(rng.integers(1, 4))
    iters = int(rng.integers(64, 512))
    bufs = [mm.alloc(f"s{i}", iters * isa.VL_ELEMS + 64)
            for i in range(n_streams)]
    a = Assembler("rand_repeat")
    with a.repeat(iters):
        for i, buf in enumerate(bufs):
            stride = int(rng.choice([4, 32, 64]))
            reg = 1 + i
            a.vle(reg, buf, stride=stride)
            if rng.random() < 0.5:
                a.vmacc(reg + n_streams, reg, reg)
            else:
                a.vmul_sc(reg + n_streams, reg, 1.5)
        a.vse(1 + n_streams, bufs[0] + 32, stride=32)
    return a.finalize(mm)


def _random_machines(rng: np.random.Generator) -> simulator.MachineSweep:
    m = 3      # fixed M: machine VALUES vary per seed, shapes stay cached
    return simulator.MachineSweep(
        l1_hit_cycles=rng.integers(0, 3, m).astype(np.int32),
        uop_hit_cycles=rng.integers(1, 4, m).astype(np.int32),
        mem_latency=rng.integers(1, 12, m).astype(np.int32))


def _check_fold_exact_implies_equal(program, machines):
    """The property: wherever the engine certifies ``fold_exact``, the
    algebraically extrapolated counters equal the full unfolded simulation
    — independently at every (capacity, machine) grid point."""
    sweep = simulator.SweepConfig.make([3, 8])
    fold = api.sweep_program(program, sweep, machines, fold=True)
    if "fold_exact" not in fold:
        return                                    # nothing folded: vacuous
    full = api.sweep_program(program, sweep, machines)
    exact = fold["fold_exact"]
    assert exact.shape == full["cycles"].shape
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(
            fold[k][exact], full[k][exact],
            err_msg=f"{k}: certified-exact fold diverged from full run")


# The deterministic seed pins run regardless of hypothesis availability:
# seed 4 is the draw that exposed the non-stationary-reuse certification
# hole, and a random strategy would almost never resample it.  The wider
# sweep rides the slow tier; with hypothesis installed an extra randomized
# search runs on top.
@pytest.mark.parametrize("seed", (0, 2, 4))
def test_fold_exact_property_random_programs(seed):
    rng = np.random.default_rng(seed)
    _check_fold_exact_implies_equal(
        _random_repeat_program(rng), _random_machines(rng))


@pytest.mark.slow
@pytest.mark.parametrize("seed", (1, 3, *range(5, 30)))
def test_fold_exact_property_random_programs_exhaustive(seed):
    rng = np.random.default_rng(seed)
    _check_fold_exact_implies_equal(
        _random_repeat_program(rng), _random_machines(rng))


if HAVE_HYP:                                          # pragma: no cover
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_fold_exact_property_hypothesis(seed):
        rng = np.random.default_rng(seed)
        _check_fold_exact_implies_equal(
            _random_repeat_program(rng), _random_machines(rng))


# ---------------------------------------------------------------------------
# State-snapshot super-period detection (multi-iteration steady states).
# ---------------------------------------------------------------------------


def test_super_period_detection_ping_pong():
    """jacobi2d's ping-pong time loop is periodic with period TWO steps —
    a loop the Assembler never emitted as one repeat.  The detector must
    find the k = 2 super-period spanning the per-step row-loop blocks."""
    p = jacobi2d.build(n=16, steps=8).program
    sup = folding.detect_super_periods(p)
    assert len(sup) == 1
    nd = sup[0]
    assert nd.cnt == 4                       # 8 steps / k=2 per period
    assert nd.bl * nd.cnt == p.num_instructions
    assert nd.warm >= 1


def test_fold_exact_jacobi2d_ping_pong():
    """The certified ping-pong fold must be bit-identical to the unfolded
    run at every (capacity, policy, machine) grid point."""
    from repro.core import policies
    p = jacobi2d.build(n=32, steps=8).program
    plan = folding.plan(p)
    assert plan is not None and plan.certifiable
    assert plan.num_super_periods == 1
    sweep = simulator.SweepConfig.product(
        [3, 8, 32], [policies.FIFO, policies.LRU])
    machines = simulator.MachineSweep.make((1, 10))
    full = api.sweep_program(p, sweep, machines)
    fold = api.sweep_program(p, sweep, machines, fold=True)
    assert fold["fold_exact"].all()
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(full[k], fold[k], err_msg=k)


@pytest.mark.slow
def test_fold_exact_jacobi2d_paper():
    """Paper size: the exact-outer ping-pong plan extrapolates the 10-step
    run bit-identically."""
    _assert_fold_exact(jacobi2d.build(**jacobi2d.PAPER).program,
                       caps=(3, 8))


def test_fold_exact_deep_nest_kernels():
    """The new 4-level-stride kernels certify their outermost (batch /
    head) loop exact: way-span-padded planes make consecutive iterations
    set-congruent, and the fold is bit-identical to the unfolded run.  (A
    4 KB L1 keeps the warm-up short enough for the small builds to fold.)
    """
    small_l1 = simulator.MachineParams(l1_sets=64)
    for mod, kw in ((conv2d_batched, dict(n=16, f=3, batch=8, cin=2)),
                    (mha, dict(seq=16, d=16, bc=16, heads=8))):
        p = mod.build(**kw).program
        plan = folding.plan(p, warm_lines=folding.warm_lines_for(64, 2))
        assert plan is not None and plan.certifiable, mod.__name__
        _assert_fold_exact(p, caps=(3, 8), machine=small_l1)


def test_exact_outer_replan_is_flagged():
    """jacobi2d paper size: the nested plan cannot certify (inner row-loop
    folds drop lines the next step reuses), so plan() must fall back to the
    certified exact-outer plan."""
    plan = folding.plan(jacobi2d.build(**jacobi2d.PAPER).program)
    assert plan.certifiable and plan.exact_outer
    assert plan.num_super_periods == 1
    assert plan.kept_fraction < 0.7          # warm + A + B of 5 periods


# ---------------------------------------------------------------------------
# somier: the carried-over within-step certification item, made executable.
# ---------------------------------------------------------------------------


def test_somier_paper_uncertified_with_diagnosis():
    """Paper-size somier (2 time steps) stays HONESTLY uncertified, and
    ``folding.diagnose`` pins exactly which invariant fails.

    The per-step force/integrate blocks are individually fine — every
    top-level block is foldable with *stationary* cross-period reuse gaps
    (the multi-rate streams inside one step are translation-invariant at
    the i-loop level).  What fails is CROSS-step: folding a step's i-loop
    drops iterations whose lines the next step re-touches, so the runtime
    A == B check cannot see the post-loop divergence, and the step-level
    super-period detector cannot rescue it because 2 steps give it only
    m = 4 adjacent blocks — below the >= 4 *periods* (8 blocks at k = 2)
    it requires.  See test_somier_step_super_period_certifies_at_4_steps
    for the converse."""
    p = somier.build(**somier.PAPER).program
    diags = [d for d in folding.diagnose(p) if not d["super_period"]]
    assert diags and all(d["foldable"] for d in diags)
    assert all(d["stationary"] for d in diags), (
        "within-step streams became non-stationary; update the somier "
        "truth-table story")
    assert folding.detect_super_periods(p) == []    # 2 steps < 4 periods
    plan = folding.plan(p)
    assert plan is not None and not plan.certifiable


def test_somier_step_super_period_certifies_at_4_steps():
    """With >= 4 time steps the state-snapshot detector finds the whole
    force+integrate step (k = 2 blocks) as a super-period and plan()
    certifies it — bit-identical to the unfolded run.  This is the
    regression guard for the somier ROADMAP item: the paper-size pin above
    is a detector-minimum limitation, not a folding-engine bug."""
    p = somier.build(n=8, steps=4).program
    sup = folding.detect_super_periods(p)
    assert len(sup) == 1 and sup[0].cnt >= 4
    plan = folding.plan(p)
    assert plan is not None and plan.certifiable
    assert plan.num_super_periods == 1
    _assert_fold_exact(p, caps=(3, 8))


# ---------------------------------------------------------------------------
# Regression pin: fold_exact truth per kernel must not silently flip.
# ---------------------------------------------------------------------------

# Paper-size certification status (at capacity 8, the paper's design point).
# dropout/gemv stream steadily and certify exact; jacobi2d's ping-pong time
# loop certifies through the state-snapshot super-period detector (k = 2
# steps, exact-outer plan); conv2d_batched/mha certify their set-congruent
# batch/head loops.  somier stays HONESTLY inexact — the somier tests
# above pin the diagnosis: each force/integrate block is individually
# stationary, but folding one step drops iterations whose lines the NEXT
# step re-touches (post-loop divergence), and the paper's 2 steps give the
# step-level detector fewer than the >= 4 periods it needs (steps >= 4
# certifies through the whole-step super-period).  A folding change that
# flips any of these silently is a certification bug.  This table is
# mirrored in docs/folding.md — keep both in sync.
FOLD_EXACT_TRUTH = {
    conv2d_batched: True,
    dropout: True,
    gemv: True,
    jacobi2d: True,
    mha: True,
    somier: False,
}


@pytest.mark.parametrize("mod", sorted(FOLD_EXACT_TRUTH, key=lambda m:
                                       m.__name__))
def test_fold_exact_certification_pinned(mod):
    from benchmarks import common    # shares paper-size builds + fold plans
    name = mod.__name__.rsplit(".", 1)[-1]
    prep = common.prepared_for(name, fold=True)
    out = simulator.simulate_grid([prep], simulator.SweepConfig.make([8]))
    assert "fold_exact" in out, f"{name} no longer folds at all"
    assert bool(out["fold_exact"].all()) is FOLD_EXACT_TRUTH[mod], (
        f"{name}: fold_exact certification flipped")
