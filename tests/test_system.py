"""End-to-end behaviour: the paper's pipeline from kernel source to
area/power verdict, exercised at reduced scale."""

import numpy as np

from repro import api, rvv
from repro.core import costmodel, events, interpreter, planner, simulator


def test_end_to_end_dispersion_study():
    """Build a kernel -> validate numerics -> sweep cVRF sizes -> confirm
    the paper's qualitative claims at reduced scale."""
    b = rvv.BENCHMARKS["gemv"]
    built = b.build(m=32, k=64)
    res = interpreter.run(built.program)
    rvv.check(built, res.memory)

    caps = [3, 4, 5, 6, 8]
    sweep = simulator.SweepConfig.make(caps + [32])
    out = api.sweep_program(built.program, sweep)
    full = out["cycles"][-1]
    perf = full / out["cycles"][:-1]
    # performance is monotone in capacity and reaches ~full at 8
    assert all(perf[i] <= perf[i + 1] + 1e-9 for i in range(len(caps) - 1))
    assert perf[-1] > 0.97

    plan = planner.min_registers_for_hit_rate(built.program)
    assert plan.min_capacity <= 8          # the paper's headline

    c8 = simulator.simulate_one(built.program, 8)
    c32 = simulator.simulate_one(built.program, 32)
    p8 = costmodel.application_power(c8, 8, c8["cycles"], dispersed=True)
    p32 = costmodel.application_power(c32, 32, c32["cycles"])
    assert p8["total"] < p32["total"]      # dispersion saves power


def test_policy_headroom_api():
    b = rvv.BENCHMARKS["pathfinder"]
    built = b.build(**b.reduced_params)
    out = planner.policy_headroom(built.program, capacities=(3, 4))
    assert set(out) == {"fifo", "lru", "lfu", "opt"}
    for cap in (3, 4):
        assert out["opt"][cap] >= out["fifo"][cap] - 1e-9
