"""Harness robustness: every benchmark suite's run() yields sane rows at
reduced event budgets (keeps the paper tables regenerable)."""

import pytest

# Fast-tier kernel subset: skips the two expensive paper-size trace builds
# (resnet50_l10, flashattention2 — both covered at reduced size by the
# conformance matrix and at paper size by `make bench` / the slow tier).
NAMES = ("pathfinder", "jacobi2d", "somier", "gemv", "dropout",
         "conv2d_7x7", "densenet121_l105")


@pytest.mark.parametrize("mod,kw", [
    ("benchmarks.table3_speedup", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig4_cvrf_sweep", {"names": ["dropout"],
                                    "max_events": 12_000}),
    ("benchmarks.fig5_min_regs", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig6_equal_area", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig2_area_model", {}),
    ("benchmarks.fig8_power", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.pareto_frontier", {"max_events": 12_000,
                                    "names": ["dropout", "gemv"]}),
    ("benchmarks.vmem_dispersion", {}),
    ("benchmarks.kv_dispersion", {"steps": 150}),
    ("benchmarks.network_sweep", {"models": ("granite-8b",), "caps": (4, 8),
                                  "l1_kbytes": (4,), "max_events": 120}),
    ("benchmarks.cluster_sweep", {"names": ("dropout",), "cores": (1, 2),
                                  "caps": (4,), "l1_kbytes": (4,),
                                  "max_events": 4000}),
    # The machine-latency grid is traced (no per-machine rebuilds), but the
    # fast suite already exercises this run in tests/test_machine_grid.py,
    # so the harness duplicate stays out of the default selection.
    pytest.param("benchmarks.ablation_sensitivity", {"max_events": 20_000},
                 marks=pytest.mark.slow),
])
def test_suite_produces_rows(mod, kw):
    m = __import__(mod, fromlist=["run"])
    rows = m.run(**kw)
    assert len(rows) > 0
    for r in rows:
        assert "name" in r


def test_run_json_schema(tmp_path):
    """The front door's --json report: schema 7, --kernels subsetting, the
    metric-registry catalog (incl. the macro-model catalog), and per-sweep
    derived-metric metadata."""
    import json

    from benchmarks import run as runner
    out = tmp_path / "bench.json"
    rc = runner.main(["--json", str(out), "--kernels", "dropout",
                      "--max-events", "12000", "fig2", "fig6"])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == 7
    assert set(rep["macro_models"]) >= {"flop", "sram6t", "table"}
    assert rep["metrics"]["silicon_area"]["kind"] == "model"
    assert rep["metrics"]["speedup"]["kind"] == "relational"
    assert rep["metrics"]["application_power"]["kind"] == "model"
    fig6 = rep["suites"]["fig6"]
    assert fig6["rows"] == 1                      # --kernels took effect
    derived = [d["metric"] for s in fig6["sweeps"] for d in s["derived"]]
    assert "equal_area_advantage" in derived and "speedup" in derived
    assert runner.main(["nope"]) == 2


def test_roofline_report_over_results():
    """The measured roofline needs no results/dryrun sweep: the smoke grid
    must emit >0 rows, each cross-checked against hbm_traffic_model."""
    import benchmarks.roofline as rl
    gemm, flash, rows = rl.run_measured(smoke=True)
    assert len(rows) > 0
    for r in rows:
        assert r["model_agree"] is True          # counted == closed form
        assert r["counted_bytes"] > 0 and r["model_bytes"] > 0
        assert r["us_per_call"] > 0
    # W and precision are labeled SweepResult axes with registry metrics
    assert [a.name for a in gemm.axes] == ["case", "working_set",
                                           "precision"]
    assert 0 in gemm.axis("working_set").values  # the dispersed extreme
    for grid in (gemm, flash):
        assert "arithmetic_intensity" in grid.data
        assert "achieved_gflops" in grid.data
    extra = rl.json_extra()
    assert len(extra["rows"]) == len(rows)
    stats = rl.perf_stats()
    assert stats["dispatches"] > 0 and stats["compiles"] > 0


def test_roofline_json_extra_schema_guard(tmp_path):
    """The regression this PR fixes: the front door must never again record
    a silent 0-row roofline.  Runs the suite through run.py --json and
    pins rows/dispatches > 0 plus the measured/model row schema."""
    import json

    from benchmarks import run as runner
    out = tmp_path / "roofline.json"
    rc = runner.main(["--json", str(out), "--max-events", "120",
                      "roofline"])
    assert rc == 0
    rep = json.loads(out.read_text())["suites"]["roofline"]
    assert rep["rows"] > 0
    assert rep["dispatches"] > 0
    for row in rep["extra"]["rows"]:
        for key in ("us_per_call", "counted_bytes", "model_bytes",
                    "model_agree", "working_set", "precision"):
            assert key in row, key
    assert set(rep["extra"]["axes"]) == {"case", "working_set", "precision"}


def test_roofline_int8_precision_point():
    """int8 is a first-class roofline precision: operands stream at one
    byte per element (the model halves again from bf16) while the f32
    accumulator terms stay fixed, and counted == model still holds on the
    measured point."""
    import benchmarks.roofline as rl
    assert "int8" in rl.PRECISIONS
    assert rl._BYTES["int8"] == 1
    p8 = rl._gemm_point("g", 128, 256, 128, 1, "int8", block_m=64,
                        block_k=128, interpret=True, repeats=1)
    p16 = rl._gemm_point("g", 128, 256, 128, 1, "bf16", block_m=64,
                         block_k=128, interpret=True, repeats=1)
    assert p8["model_agree"] is True and p16["model_agree"] is True
    # grouped W>=1 traffic is pure operand streaming: exactly bpe-linear
    assert p8["model_bytes"] * 2 == p16["model_bytes"]
    assert p8["name"].endswith("_int8")
    """The legacy dry-run table: warns (instead of silently emitting
    nothing) when results/dryrun is absent; load_cells reports corrupt
    cells instead of swallowing them."""
    import os
    import benchmarks.roofline as rl
    if not os.path.isdir(rl.RESULTS):
        with pytest.warns(UserWarning, match="dry-run sweep"):
            assert rl.run("single") == []
    else:
        rows = rl.run("single")
        ok = [r for r in rows if r.get("status") == "ok"]
        for r in ok:
            assert r["bottleneck"] in ("compute", "memory", "collective")


def test_roofline_load_cells_reports_corrupt_files(tmp_path, monkeypatch):
    import benchmarks.roofline as rl
    good = [dict(arch="a", shape="s", status="skip")]
    (tmp_path / "a_single.json").write_text("{corrupt")
    import json
    (tmp_path / "b_single.json").write_text(json.dumps(good))
    monkeypatch.setattr(rl, "RESULTS", str(tmp_path))
    with pytest.warns(UserWarning, match="skipped 1 unreadable"):
        cells = rl.load_cells("single")
    assert cells == good
