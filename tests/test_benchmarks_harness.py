"""Harness robustness: every benchmark suite's run() yields sane rows at
reduced event budgets (keeps the paper tables regenerable)."""

import pytest

# Fast-tier kernel subset: skips the two expensive paper-size trace builds
# (resnet50_l10, flashattention2 — both covered at reduced size by the
# conformance matrix and at paper size by `make bench` / the slow tier).
NAMES = ("pathfinder", "jacobi2d", "somier", "gemv", "dropout",
         "conv2d_7x7", "densenet121_l105")


@pytest.mark.parametrize("mod,kw", [
    ("benchmarks.table3_speedup", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig4_cvrf_sweep", {"names": ["dropout"],
                                    "max_events": 12_000}),
    ("benchmarks.fig5_min_regs", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig6_equal_area", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.fig2_area_model", {}),
    ("benchmarks.fig8_power", {"max_events": 12_000, "names": NAMES}),
    ("benchmarks.pareto_frontier", {"max_events": 12_000,
                                    "names": ["dropout", "gemv"]}),
    ("benchmarks.vmem_dispersion", {}),
    ("benchmarks.kv_dispersion", {"steps": 150}),
    # The machine-latency grid is traced (no per-machine rebuilds), but the
    # fast suite already exercises this run in tests/test_machine_grid.py,
    # so the harness duplicate stays out of the default selection.
    pytest.param("benchmarks.ablation_sensitivity", {"max_events": 20_000},
                 marks=pytest.mark.slow),
])
def test_suite_produces_rows(mod, kw):
    m = __import__(mod, fromlist=["run"])
    rows = m.run(**kw)
    assert len(rows) > 0
    for r in rows:
        assert "name" in r


def test_run_json_schema(tmp_path):
    """The front door's --json report: schema 4, --kernels subsetting, the
    metric-registry catalog, and per-sweep derived-metric metadata."""
    import json

    from benchmarks import run as runner
    out = tmp_path / "bench.json"
    rc = runner.main(["--json", str(out), "--kernels", "dropout",
                      "--max-events", "12000", "fig2", "fig6"])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == 4
    assert rep["metrics"]["speedup"]["kind"] == "relational"
    assert rep["metrics"]["application_power"]["kind"] == "model"
    fig6 = rep["suites"]["fig6"]
    assert fig6["rows"] == 1                      # --kernels took effect
    derived = [d["metric"] for s in fig6["sweeps"] for d in s["derived"]]
    assert "equal_area_advantage" in derived and "speedup" in derived
    assert runner.main(["nope"]) == 2


def test_roofline_report_over_results():
    import os
    import benchmarks.roofline as rl
    if not os.path.isdir(rl.RESULTS):
        pytest.skip("no sweep results present")
    rows = rl.run("single")
    assert any(r.get("status") == "ok" for r in rows)
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in ok:
        assert r["bottleneck"] in ("compute", "memory", "collective")
