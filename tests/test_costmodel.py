"""Area/power model: calibration identities + untuned predictions."""

from repro.core import costmodel


def test_baseline_breakdown_matches_paper():
    full = costmodel.cpu_area(32)
    assert abs(100 * full.vrf / full.vpu - 61.0) < 0.5          # Fig 2
    assert abs(100 * full.vpu / full.total - 43.4) < 0.5        # derived


def test_savings_predictions_match_paper():
    full = costmodel.cpu_area(32)
    cvrf = costmodel.cpu_area(8, dispersed=True)
    red = full.vrf / (cvrf.vrf + cvrf.dispersion_overhead)
    assert abs(red - 3.5) < 0.1                                 # 3.5x
    assert abs(100 * (1 - cvrf.vpu / full.vpu) - 53.0) < 1.0    # 53%
    assert abs(100 * (1 - cvrf.total / full.total) - 23.0) < 1.0  # 23%


def test_narrow_vrf_is_equal_area():
    # Fig 6 premise: 8 x 256-bit ~= 32 x 64-bit in area.
    cvrf = costmodel.cpu_area(8, vlen_bits=256, dispersed=True)
    narrow = costmodel.cpu_area(32, vlen_bits=64)
    assert abs(cvrf.vrf - narrow.vrf) / narrow.vrf < 0.15


def test_power_components_positive():
    counters = dict(reg_reads=1000, reg_writes=500, l1_hits=300,
                    l1_misses=20, mem_reads=100, mem_writes=50, cycles=2000)
    p = costmodel.application_power(counters, 32, 2000)
    assert p["total"] > 0 and p["leakage"] > 0 and p["dynamic"] > 0
