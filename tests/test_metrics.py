"""Metric algebra over SweepResult: registry round-trip, vectorized model
paths bit-equal to the scalar costmodel, normalize/pareto pinned on a
hand-checkable grid, baseline alignment on product and zipped axes, and
the paper-headline rows through the new API.
"""

import numpy as np
import pytest

from repro import api, metrics
from repro.core import costmodel, policies

# ---------------------------------------------------------------------------
# A hand-checkable toy grid: kernel ("a", "b") x capacity (8, 32), every
# other axis a singleton, counters chosen so every metric is mental math.
# ---------------------------------------------------------------------------


def toy_result() -> api.SweepResult:
    axes = (
        api.Axis("kernel", ("a", "b")),
        api.Axis("capacity", (8, 32)),
        api.Axis("policy", (policies.FIFO,)),
        api.Axis("alloc_no_fetch", (False,)),
        api.Axis("l1_geometry", (api.L1Geometry(256, 2),)),
        api.Axis("mem_latency", (5,)),
        api.Axis("l1_hit_cycles", (0,)),
        api.Axis("uop_hit_cycles", (1,)),
    )
    shape = (2, 2, 1, 1, 1, 1, 1, 1)

    def grid(a_vals, b_vals):
        return np.asarray([a_vals, b_vals], np.int64).reshape(shape)

    data = dict(
        cycles=grid([200, 100], [400, 400]),     # "a" 2x slower at cVRF-8
        stall_cycles=grid([50, 0], [100, 100]),
        spills=grid([4, 0], [8, 0]),
        fills=grid([6, 0], [2, 0]),
        l1_hits=grid([10, 10], [20, 20]),
        l1_misses=grid([2, 2], [4, 4]),
        reg_reads=grid([30, 30], [60, 60]),
        reg_writes=grid([10, 10], [20, 20]),
        mem_reads=grid([2, 2], [4, 4]),
        mem_writes=grid([1, 1], [2, 2]),
        vrf_hits=grid([90, 100], [180, 200]),
        vrf_misses=grid([10, 0], [20, 0]),
    )
    data["hit_rate"] = data["vrf_hits"] / (data["vrf_hits"]
                                           + data["vrf_misses"])
    data["event_scale"] = np.full(shape, 1.0)
    data["fold_exact"] = np.ones(shape, bool)
    return api.SweepResult(axes, data, dict(kernel_params="paper"))


# ---------------------------------------------------------------------------
# Registry round-trip.
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    assert {"speedup", "application_power", "total_area",
            "narrow_vrf_cycles"} <= set(metrics.names())

    @metrics.register("test_double_cycles", "derived", "2x cycles")
    def _double(ctx):
        return ctx.counter("cycles") * 2
    try:
        m = metrics.get("test_double_cycles")
        assert m.kind == "derived" and m.doc == "2x cycles"
        assert metrics.catalog()["test_double_cycles"]["kind"] == "derived"
        r = toy_result().derive("test_double_cycles")
        np.testing.assert_array_equal(r["test_double_cycles"],
                                      r["cycles"] * 2)
        with pytest.raises(ValueError, match="registered twice"):
            metrics.register("test_double_cycles", "derived")(_double)
        metrics.register("test_double_cycles", "derived",
                         override=True)(_double)
    finally:
        metrics.unregister("test_double_cycles")
    assert "test_double_cycles" not in metrics.names()
    with pytest.raises(KeyError, match="unknown metric.*speedup"):
        metrics.get("test_double_cycles")
    with pytest.raises(ValueError, match="kind must be one of"):
        metrics.register("test_bad_kind", "pointwise")(lambda ctx: 0)


def test_kind_discipline():
    res = toy_result()
    with pytest.raises(ValueError, match="relational; pass baseline"):
        res.derive("speedup")
    with pytest.raises(ValueError, match="not relational"):
        res.derive("scaled_cycles", baseline=dict(capacity=32))
    with pytest.raises(KeyError, match="unknown metric"):
        res.derive("nope")
    with pytest.raises(TypeError, match="unknown parameter.*bogus"):
        res.derive("speedup", baseline=dict(capacity=32), bogus=1)


def test_params_propagate_through_composition():
    """derive() parameters reach metrics pulled in via ctx.counter —
    and parameterised evaluations never poison the canonical-name cache."""
    res = toy_result()
    cheap = costmodel.PowerParams(e_alu_op=0.0, e_l1_access=0.0)
    default = res.derive("energy")
    custom = res.derive("energy", pp=cheap)
    assert (custom["energy"] < default["energy"]).all()
    # the pp-specific application_power must not ride along under its
    # canonical name (it would poison later parameter-free reads) ...
    assert "application_power" not in custom.keys()
    # ... while the parameter-free derive caches it as usual.
    assert "application_power" in default.keys()
    np.testing.assert_array_equal(
        custom.derive("application_power")["application_power"],
        default["application_power"])


# ---------------------------------------------------------------------------
# Vectorized model paths bit-equal to the scalar costmodel.
# ---------------------------------------------------------------------------


def test_cpu_area_grid_bit_equal_scalar():
    n = np.arange(1, 41)
    for dispersed in (False, True):
        grids = costmodel.cpu_area_grid(n, dispersed=dispersed)
        for i, nv in enumerate(n):
            rep = costmodel.cpu_area(int(nv), dispersed=dispersed)
            for key, want in rep.as_dict().items():
                assert grids[key][i] == want, (key, nv, dispersed)


def test_application_power_grid_bit_equal_scalar():
    rng = np.random.default_rng(7)
    shape = (3, 4)
    counters = {k: rng.integers(0, 100_000, shape)
                for k in ("reg_reads", "reg_writes", "l1_hits", "l1_misses",
                          "mem_reads", "mem_writes", "cycles")}
    n_vregs = np.asarray([4, 8, 32]).reshape(3, 1)
    dispersed = n_vregs < 32
    grids = costmodel.application_power_grid(counters, n_vregs,
                                             dispersed=dispersed)
    for idx in np.ndindex(*shape):
        point = {k: float(v[idx]) for k, v in counters.items()}
        want = costmodel.application_power(
            point, int(np.broadcast_to(n_vregs, shape)[idx]),
            point["cycles"],
            dispersed=bool(np.broadcast_to(dispersed, shape)[idx]))
        for key, v in want.items():
            assert grids[key][idx] == v, (key, idx)


def test_model_metrics_bit_equal_on_real_grid():
    """The acceptance pin: the vectorized model metrics reproduce the old
    per-point scalar loops exactly on an ablation-style grid — and derive
    never compiles or dispatches."""
    ses = api.Session(refine=False)
    res = ses.run(api.Sweep(kernels=("dropout", "gemv"),
                            capacity=(4, 8, 32), mem_latency=(1, 5),
                            kernel_params="reduced"))
    c0, d0 = ses.compile_count(), ses.dispatch_count()
    r = (res.derive("application_power").derive("total_area")
            .derive("vpu_area").derive("narrow_vrf_cycles"))
    assert (ses.compile_count(), ses.dispatch_count()) == (c0, d0)
    for row in res.to_rows():
        pt = dict(kernel=row["kernel"], capacity=row["capacity"],
                  mem_latency=row["mem_latency"])
        counters = {k: float(res.value(k, **pt)) for k in res.keys()}
        dispersed = row["capacity"] < 32
        power = costmodel.application_power(
            counters, row["capacity"], counters["cycles"],
            dispersed=dispersed)
        area = costmodel.cpu_area(row["capacity"], dispersed=dispersed)
        assert r.value("application_power", **pt) == power["total"]
        assert r.value("total_area", **pt) == area.total
        assert r.value("vpu_area", **pt) == area.vpu
        # fig6's old hardcoded narrow machine (hit=1, miss=1+5) is the
        # mem_latency=5 point of the metric's machine-axis parameterised
        # model.
        if row["mem_latency"] == 5:
            mem = counters["l1_hits"] * 1 + counters["l1_misses"] * (1 + 5)
            comp = counters["cycles"] - mem
            nacc = (counters["l1_hits"] + counters["l1_misses"]) * 4
            want = (4.0 * comp + (nacc - counters["l1_misses"]) * 1
                    + counters["l1_misses"] * (1 + 5))
            assert r.value("narrow_vrf_cycles", **pt) == want


# ---------------------------------------------------------------------------
# normalize / relational baselines / pareto on the toy grid.
# ---------------------------------------------------------------------------


def test_normalize_pinned():
    r = toy_result().normalize("cycles", baseline=dict(capacity=32))
    np.testing.assert_array_equal(
        np.squeeze(r["cycles"]), [[2.0, 1.0], [1.0, 1.0]])
    # other counters untouched
    np.testing.assert_array_equal(r["spills"], toy_result()["spills"])


def test_speedup_and_savings_pinned():
    res = toy_result()
    r = (res.derive("speedup", baseline=dict(capacity=32))
            .derive("savings_pct", of="cycles", baseline=dict(kernel="b"),
                    out="vs_b"))
    np.testing.assert_array_equal(
        np.squeeze(r["speedup"]), [[0.5, 1.0], [1.0, 1.0]])
    # savings vs kernel "b": a@8 saves 50% of 400, a@32 saves 75%.
    np.testing.assert_array_equal(
        np.squeeze(r["vs_b"]), [[50.0, 75.0], [0.0, 0.0]])
    assert r.value("speedup", kernel="a", capacity=8) == 0.5
    with pytest.raises(ValueError, match="pin exactly one"):
        res.derive("speedup", baseline=dict(capacity=[8, 32]))
    with pytest.raises(KeyError, match="unknown baseline axis"):
        res.derive("speedup", baseline=dict(not_an_axis=1))


def test_derived_metrics_pinned():
    r = toy_result().derive("spill_traffic_bytes").derive("scaled_cycles")
    np.testing.assert_array_equal(
        np.squeeze(r["spill_traffic_bytes"]), [[320, 0], [320, 0]])
    np.testing.assert_array_equal(r["scaled_cycles"], r["cycles"] * 1.0)


def test_pareto_pinned():
    res = toy_result()
    # kernel "a": area grows with capacity, cycles shrink -> both points
    # on the front; kernel "b": cycles equal, so capacity 32 is dominated
    # (same cycles, more area) and only capacity 8 survives.
    r = res.derive("total_area")
    front_a = r.pareto(x="total_area", y="cycles", kernel="a")
    assert [f["capacity"] for f in front_a] == [8, 32]
    assert front_a[0]["kernel"] == "a" and front_a[0]["cycles"] == 200.0
    front_b = r.pareto(x="total_area", y="cycles", kernel="b")
    assert [f["capacity"] for f in front_b] == [8]
    # maximize flips an axis: the largest-area point is now the x-winner.
    front_max = r.pareto(x="total_area", y="cycles",
                         maximize=("total_area",), kernel="b")
    assert [f["capacity"] for f in front_max] == [32]
    # derived on demand: pareto derives registered metrics it is given.
    assert res.pareto(x="total_area", y="cycles", kernel="b")


def test_baseline_field_match_on_zipped_config(fresh_default_session):
    pts = [api.ConfigPoint(4, policies.FIFO),
           api.ConfigPoint(4, policies.LRU),
           api.ConfigPoint(8, policies.FIFO),
           api.ConfigPoint(8, policies.FIFO, True)]
    res = fresh_default_session.run(
        api.Sweep(kernels=["dropout"], config_points=pts,
                  kernel_params="reduced"))
    r = (res.derive("speedup", baseline=dict(policy="fifo",
                                             alloc_no_fetch=False))
            .derive("delta", of="hit_rate",
                    baseline=dict(policy="fifo", alloc_no_fetch=False),
                    out="hit_gain"))
    # FIFO points are their own baseline...
    assert r.value("speedup", capacity=4, policy="fifo",
                   alloc_no_fetch=False) == 1.0
    assert r.value("hit_gain", capacity=8, policy="fifo",
                   alloc_no_fetch=False) == 0.0
    # ...and each capacity aligns against ITS OWN FIFO point.
    want = (res.value("cycles", capacity=4, policy="fifo")
            / res.value("cycles", capacity=4, policy="lru"))
    assert r.value("speedup", capacity=4, policy="lru") == want
    with pytest.raises(ValueError, match="no baseline config point"):
        res.derive("speedup", baseline=dict(policy="opt"))


# ---------------------------------------------------------------------------
# The paper-headline rows through the new API (fast tier).
# ---------------------------------------------------------------------------


def test_area_headlines_through_metrics():
    head = metrics.area_headline()
    assert abs(head["baseline_vrf_pct_of_vpu"] - 61.0) < 0.5
    assert abs(head["vrf_area_reduction_x"] - 3.5) < 0.1       # 3.5x
    assert abs(head["vpu_area_saving_pct"] - 53.0) < 1.0       # 53%
    assert abs(head["total_area_saving_pct"] - 23.0) < 1.0     # 23%


def test_power_and_equal_area_headlines_through_suites():
    """Fig 8's ~10% average power saving and Fig 6's dispersion-beats-
    narrowing verdict, asserted through the rewired metric-query suites
    (fast-tier kernel subset + truncated traces, as the harness runs)."""
    from benchmarks import fig6_equal_area, fig8_power
    # The harness's fast-tier kernel subset (tests/test_benchmarks_harness)
    # so the prepared-trace cache is shared within one tier-1 run.
    NAMES = ("pathfinder", "jacobi2d", "somier", "gemv", "dropout",
             "conv2d_7x7", "densenet121_l105")
    rows = fig8_power.run(max_events=12_000, names=NAMES)
    avg = next(r for r in rows if r["name"] == "AVERAGE")
    assert avg["paper_saving"] == 10.0
    assert abs(avg["saving_pct"] - 10.0) < 2.0, rows   # measured: 9.8%
    for row in fig6_equal_area.run(max_events=12_000, names=NAMES):
        assert row["advantage"] > 1.0, row
        assert row["narrow_32x64"] < row["dispersion_8x256"], row
