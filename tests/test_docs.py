"""Docs stay executable: the README quickstart block and
examples/quickstart.py must run against the current API.

`make docs-check` runs exactly this file; it also rides the fast tier so a
PR that breaks a documented snippet fails the tier-1 gate.
"""

import pathlib
import re
import runpy

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _python_blocks(md_path):
    text = md_path.read_text()
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


def test_readme_exists_with_quickstart():
    blocks = _python_blocks(ROOT / "README.md")
    assert blocks, "README.md lost its ```python quickstart block"


def test_readme_quickstart_block_executes():
    for block in _python_blocks(ROOT / "README.md"):
        exec(compile(block, "README.md", "exec"), {})


def test_docs_pages_exist():
    for page in ("api.md", "architecture.md", "bridge.md", "cluster.md",
                 "folding.md", "kernels.md", "metrics.md", "serving.md",
                 "silicon.md"):
        text = (ROOT / "docs" / page).read_text()
        assert len(text) > 500, page


def test_metrics_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "metrics.md")
    assert blocks, "docs/metrics.md lost its ```python examples"
    for block in blocks:
        exec(compile(block, "docs/metrics.md", "exec"), {})


def test_kernels_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "kernels.md")
    assert blocks, "docs/kernels.md lost its ```python roofline example"
    for block in blocks:
        exec(compile(block, "docs/kernels.md", "exec"), {})


def test_serving_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "serving.md")
    assert blocks, "docs/serving.md lost its ```python example"
    for block in blocks:
        exec(compile(block, "docs/serving.md", "exec"), {})


def test_bridge_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "bridge.md")
    assert blocks, "docs/bridge.md lost its ```python lowering examples"
    for block in blocks:
        exec(compile(block, "docs/bridge.md", "exec"), {})


def test_cluster_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "cluster.md")
    assert blocks, "docs/cluster.md lost its ```python sweep example"
    for block in blocks:
        exec(compile(block, "docs/cluster.md", "exec"), {})


def test_silicon_doc_blocks_execute():
    blocks = _python_blocks(ROOT / "docs" / "silicon.md")
    assert blocks, "docs/silicon.md lost its ```python macro-model examples"
    for block in blocks:
        exec(compile(block, "docs/silicon.md", "exec"), {})


def test_examples_quickstart_runs():
    runpy.run_path(str(ROOT / "examples" / "quickstart.py"),
                   run_name="__main__")
