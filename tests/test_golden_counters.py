"""Golden counter regression + differential conformance matrix.

Part 1 pins ``simulate_one`` counters for a small GEMM and
FlashAttention-2 trace at capacities {3, 8, 32} x {FIFO, LRU}.  The values
were captured from the original per-event scan engine; the fused
instruction-level engine must reproduce them bit-for-bit (the engine
refactor is behaviour-preserving on unfolded traces).

Part 2 runs EVERY ``rvv/`` kernel (reduced size) through both the fused
jax engine and the numpy reference interpreter at three (capacity, policy,
machine) grid points — one per replacement policy FIFO / LRU / OPT (the
OPT row needs the interpreter's Belady ``next_use`` pre-pass) — and
asserts bit-identical dispersion counters.  The machine latencies are
traced sweep axes, so this doubles as the check that latency parameters
never leak into a replacement decision: the interpreter has no timing
model at all, yet must agree at every machine point.
"""

import numpy as np
import pytest

from repro import rvv
from repro.core import interpreter, policies, simulator

# (kernel, capacity, policy) -> counters from the per-event seed engine.
GOLDEN = {
    ("densenet121_l105", 3, policies.FIFO): dict(
        cycles=885, stall_cycles=74, spills=22, fills=32, l1_hits=266,
        l1_misses=53, vrf_hits=633, vrf_misses=32),
    ("densenet121_l105", 3, policies.LRU): dict(
        cycles=871, stall_cycles=60, spills=15, fills=25, l1_hits=252,
        l1_misses=53, vrf_hits=640, vrf_misses=25),
    ("densenet121_l105", 8, policies.FIFO): dict(
        cycles=835, stall_cycles=24, spills=0, fills=4, l1_hits=216,
        l1_misses=53, vrf_hits=661, vrf_misses=4),
    ("densenet121_l105", 8, policies.LRU): dict(
        cycles=835, stall_cycles=24, spills=0, fills=4, l1_hits=216,
        l1_misses=53, vrf_hits=661, vrf_misses=4),
    ("densenet121_l105", 32, policies.FIFO): dict(
        cycles=811, stall_cycles=0, spills=0, fills=0, l1_hits=216,
        l1_misses=49, vrf_hits=665, vrf_misses=0),
    ("densenet121_l105", 32, policies.LRU): dict(
        cycles=811, stall_cycles=0, spills=0, fills=0, l1_hits=216,
        l1_misses=49, vrf_hits=665, vrf_misses=0),
    ("flashattention2", 3, policies.FIFO): dict(
        cycles=9933, stall_cycles=1398, spills=540, fills=703, l1_hits=4769,
        l1_misses=170, vrf_hits=8529, vrf_misses=703),
    ("flashattention2", 3, policies.LRU): dict(
        cycles=9871, stall_cycles=1336, spills=541, fills=640, l1_hits=4707,
        l1_misses=170, vrf_hits=8592, vrf_misses=640),
    ("flashattention2", 8, policies.FIFO): dict(
        cycles=9694, stall_cycles=1159, spills=498, fills=506, l1_hits=4530,
        l1_misses=170, vrf_hits=8726, vrf_misses=506),
    ("flashattention2", 8, policies.LRU): dict(
        cycles=9698, stall_cycles=1163, spills=500, fills=508, l1_hits=4534,
        l1_misses=170, vrf_hits=8724, vrf_misses=508),
    ("flashattention2", 32, policies.FIFO): dict(
        cycles=8535, stall_cycles=0, spills=0, fills=0, l1_hits=3557,
        l1_misses=139, vrf_hits=9232, vrf_misses=0),
    ("flashattention2", 32, policies.LRU): dict(
        cycles=8535, stall_cycles=0, spills=0, fills=0, l1_hits=3557,
        l1_misses=139, vrf_hits=9232, vrf_misses=0),
}

_PROGRAMS = {}


def _program(name):
    if name not in _PROGRAMS:
        b = rvv.BENCHMARKS[name]
        _PROGRAMS[name] = b.build(**b.reduced_params).program
    return _PROGRAMS[name]


@pytest.mark.parametrize("name,cap,policy", sorted(GOLDEN))
def test_golden_counters(name, cap, policy):
    out = simulator.simulate_one(_program(name), cap, policy)
    want = GOLDEN[(name, cap, policy)]
    got = {k: int(out[k]) for k in want}
    assert got == want


# ---------------------------------------------------------------------------
# Differential conformance: fused engine vs numpy interpreter, every kernel.
# ---------------------------------------------------------------------------

# Three (capacity, policy, machine) grid points spanning FIFO, LRU and OPT.
# The machines share one L1 geometry (l1_sets/l1_ways are static engine
# parameters); their latency fields span the traced axes.  OPT conformance
# relies on the interpreter's Belady pre-pass (events.next_use_grid): both
# engines compare the identical farthest-next-use metric in the same
# (T, 3) slot-grid index space.
CONF_POINTS = (
    (3, policies.FIFO, simulator.MachineParams(mem_latency=1)),
    (4, policies.LRU, simulator.MachineParams(mem_latency=10,
                                              uop_hit_cycles=2)),
    (8, policies.OPT, simulator.MachineParams(mem_latency=5,
                                              l1_hit_cycles=1)),
)

# Counters both engines define: the interpreter moves real data and has no
# timing model, so agreement here certifies the dispersion *mechanism*.
DIFF_COUNTERS = ("vrf_hits", "vrf_misses", "spills", "fills")

_SIM_GRID = {}


def _sim_grid(name):
    """One fused (C=3, M=3) dispatch per kernel; the conformance points sit
    on its diagonal.  Cached so each kernel simulates once."""
    if name not in _SIM_GRID:
        sweep = simulator.SweepConfig(
            np.asarray([c for c, _, _ in CONF_POINTS], np.int32),
            np.asarray([p for _, p, _ in CONF_POINTS], np.int32),
            np.zeros(len(CONF_POINTS), bool))
        machines = simulator.MachineSweep.from_params(
            [m for _, _, m in CONF_POINTS])
        prep = simulator.prepare(_program(name))
        _SIM_GRID[name] = simulator.simulate_grid([prep], sweep, machines)
    return _SIM_GRID[name]


@pytest.mark.parametrize("point", range(len(CONF_POINTS)))
@pytest.mark.parametrize("name", sorted(rvv.BENCHMARKS))
def test_differential_conformance(name, point):
    cap, policy, _machine = CONF_POINTS[point]
    disp = interpreter.run_dispersed(_program(name), cap, policy)
    grid = _sim_grid(name)
    got = {k: int(grid[k][0, point, point]) for k in DIFF_COUNTERS}
    want = {k: int(getattr(disp, k)) for k in DIFF_COUNTERS}
    assert got == want


def test_conformance_counters_machine_invariant():
    """The differential counters must not move along the machine axis —
    the interpreter (no timing model) agrees at *every* machine point only
    because latencies never reach the replacement machinery."""
    grid = _sim_grid("densenet121_l105")
    for k in DIFF_COUNTERS:
        assert (grid[k] == grid[k][..., :1]).all(), k


# ---------------------------------------------------------------------------
# Bridge-lowered layer families: the same differential conformance, for the
# generated programs.  One representative per layer family (gemm / attn /
# scan) x two shapes, at the same three (capacity, policy, machine) points
# including the OPT/Belady one — every program the trace-from-model bridge
# emits must be as trustworthy as the hand-written kernels.
# ---------------------------------------------------------------------------

BRIDGE_REPRS = {
    "bridge_gemm_16x16": dict(kind="gemm", tiles=2, mt=2, k=16, n=16),
    "bridge_gemm_32x24": dict(kind="gemm", tiles=2, mt=1, k=32, n=24),
    "bridge_attn_h2d16": dict(kind="attn", seq=16, d=16, bc=16, heads=2),
    "bridge_attn_h1d16": dict(kind="attn", seq=16, d=16, bc=8, heads=1),
    "bridge_scan_w64": dict(kind="scan", steps=6, width=64),
    "bridge_scan_w128": dict(kind="scan", steps=8, width=128),
}

_BRIDGE_PROGRAMS = {}


def _bridge_program(name):
    if name not in _BRIDGE_PROGRAMS:
        from repro import bridge
        spec = dict(BRIDGE_REPRS[name])
        build = {"gemm": bridge.build_gemm, "attn": bridge.build_attn,
                 "scan": bridge.build_scan}[spec.pop("kind")]
        _BRIDGE_PROGRAMS[name] = build(**spec).program
    return _BRIDGE_PROGRAMS[name]


_BRIDGE_SIM_GRID = {}


def _bridge_sim_grid(name):
    """One fused (C=3, M=3) dispatch per bridge program, diagonal points."""
    if name not in _BRIDGE_SIM_GRID:
        sweep = simulator.SweepConfig(
            np.asarray([c for c, _, _ in CONF_POINTS], np.int32),
            np.asarray([p for _, p, _ in CONF_POINTS], np.int32),
            np.zeros(len(CONF_POINTS), bool))
        machines = simulator.MachineSweep.from_params(
            [m for _, _, m in CONF_POINTS])
        prep = simulator.prepare(_bridge_program(name))
        _BRIDGE_SIM_GRID[name] = simulator.simulate_grid(
            [prep], sweep, machines)
    return _BRIDGE_SIM_GRID[name]


@pytest.mark.parametrize("point", range(len(CONF_POINTS)))
@pytest.mark.parametrize("name", sorted(BRIDGE_REPRS))
def test_bridge_differential_conformance(name, point):
    cap, policy, _machine = CONF_POINTS[point]
    disp = interpreter.run_dispersed(_bridge_program(name), cap, policy)
    grid = _bridge_sim_grid(name)
    got = {k: int(grid[k][0, point, point]) for k in DIFF_COUNTERS}
    want = {k: int(getattr(disp, k)) for k in DIFF_COUNTERS}
    assert got == want


# ---------------------------------------------------------------------------
# Cluster N=1 passthrough: the cluster engine with one core, no shared L2
# and a non-queueing arbiter must reproduce the single-core engine counters
# BIT-exactly over the full (capacity x policy incl. OPT x machine) grid —
# the contract that makes every cluster result a strict superset of the
# conformance-checked single-core model.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["densenet121_l105", "flashattention2"])
def test_cluster_n1_passthrough_bit_identity(name):
    from repro.cluster import ClusterConfig, simulate_cluster_grid
    sweep = simulator.SweepConfig(
        np.asarray([c for c, _, _ in CONF_POINTS], np.int32),
        np.asarray([p for _, p, _ in CONF_POINTS], np.int32),
        np.zeros(len(CONF_POINTS), bool))
    machines = simulator.MachineSweep.from_params(
        [m for _, _, m in CONF_POINTS])
    single = _sim_grid(name)
    clus = simulate_cluster_grid(
        [simulator.prepare(_program(name))], sweep, machines,
        ClusterConfig.passthrough(1))
    for k in simulator.COUNTER_NAMES:
        np.testing.assert_array_equal(clus[k], single[k], err_msg=k)
    np.testing.assert_array_equal(clus["hit_rate"], single["hit_rate"])
    np.testing.assert_array_equal(clus["core_cycles_max"], single["cycles"])
    assert (clus["contention_stalls"] == 0).all()
    assert (clus["l2_hits"] == 0).all()
