"""Property-based tests (hypothesis) for the system's core invariants:

1. Register Dispersion is semantics-preserving: for ANY program and ANY
   capacity >= 3 and ANY policy, dispersed execution == full-VRF execution.
2. LRU hit rate is monotonically non-decreasing in capacity (stack property;
   note FIFO may exhibit Belady's anomaly, so no such claim for FIFO).
3. Belady-OPT hit rate >= FIFO and >= LRU at equal capacity.
4. If capacity >= #active registers, misses == compulsory fills only.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except Exception:                                     # pragma: no cover
    HAVE_HYP = False

    class _StrategyStub:
        """No-op stand-ins so module-level @st.composite / @given decorators
        still evaluate when hypothesis is absent (tests are skipped)."""

        def composite(self, f):
            return lambda *a, **k: None

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

from repro import api
from repro.core import events, interpreter, isa, policies, simulator
from repro.core.trace import Assembler, MemoryMap

pytestmark = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis missing")


@st.composite
def programs(draw):
    """Random straight-line RVV-lite programs over a small memory."""
    n_instr = draw(st.integers(4, 60))
    n_bufs = 4
    mm = MemoryMap()
    bases = [mm.alloc(f"b{i}", np.arange(32, dtype=np.float32) + i)
             for i in range(n_bufs)]
    a = Assembler("rand")
    reg = lambda: draw(st.integers(1, 12))
    for _ in range(n_instr):
        op = draw(st.integers(0, 7))
        addr = (draw(st.sampled_from(bases))
                + 32 * draw(st.integers(0, 2)))
        if op == 0:
            a.vle(reg(), addr)
        elif op == 1:
            a.vse(reg(), addr)
        elif op == 2:
            a.vadd(reg(), reg(), reg())
        elif op == 3:
            a.vmul(reg(), reg(), reg())
        elif op == 4:
            a.vmacc(reg(), reg(), reg())
        elif op == 5:
            a.vmslt(reg(), reg())
        elif op == 6:
            a.vmerge(reg(), reg(), reg())
        else:
            a.vmax(reg(), reg(), reg())
    return a.finalize(mm)


@settings(max_examples=40, deadline=None)
@given(programs(), st.integers(3, 12),
       st.sampled_from([policies.FIFO, policies.LRU, policies.LFU,
                        policies.OPT]))
def test_dispersion_semantics_preserving(prog, capacity, policy):
    full = interpreter.run(prog)
    disp = interpreter.run_dispersed(prog, capacity, policy)
    np.testing.assert_array_equal(full.memory, disp.memory)
    np.testing.assert_array_equal(full.vregs, disp.vregs)


@settings(max_examples=15, deadline=None)
@given(programs())
def test_lru_hit_rate_monotone_in_capacity(prog):
    caps = [3, 4, 6, 8, 12]
    sweep = simulator.SweepConfig.make(caps, policies.LRU)
    out = api.sweep_program(prog, sweep)
    hits = out["vrf_hits"]
    assert all(hits[i] <= hits[i + 1] for i in range(len(caps) - 1))


@settings(max_examples=15, deadline=None)
@given(programs(), st.integers(3, 8))
def test_opt_dominates_online_policies(prog, cap):
    res = {}
    for pol in (policies.FIFO, policies.LRU, policies.OPT):
        res[pol] = simulator.simulate_one(prog, cap, pol)["vrf_hits"]
    assert res[policies.OPT] >= res[policies.FIFO]
    assert res[policies.OPT] >= res[policies.LRU]


@settings(max_examples=15, deadline=None)
@given(programs())
def test_sufficient_capacity_means_compulsory_only(prog):
    active = [r for r in prog.active_vregs() if r != isa.MASK_REG]
    cap = max(len(active), 3)
    out = simulator.simulate_one(prog, cap)
    assert out["vrf_misses"] == len(active)
    assert out["spills"] == 0


@settings(max_examples=10, deadline=None)
@given(programs(), st.integers(3, 10))
def test_simulator_and_interpreter_agree_on_hit_counts(prog, cap):
    """The jax cycle simulator and the numpy dispersed interpreter implement
    the same FIFO mechanism — their hit/miss/spill counters must agree."""
    disp = interpreter.run_dispersed(prog, cap, policies.FIFO)
    sim = simulator.simulate_one(prog, cap, policies.FIFO)
    assert sim["vrf_hits"] == disp.vrf_hits
    assert sim["vrf_misses"] == disp.vrf_misses
    assert sim["spills"] == disp.spills


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(1, 3))
def test_repeat_expansion_equals_python_loop(n_outer, n_inner, stride_w):
    """Nested Assembler.repeat must emit exactly what explicit python loops
    emit (addresses, ops, registers)."""
    mm1, mm2 = MemoryMap(), MemoryMap()
    base1 = mm1.alloc("b", 512)
    base2 = mm2.alloc("b", 512)
    a1 = Assembler("rep")
    with a1.repeat(n_outer):
        with a1.repeat(n_inner):
            a1.vle(1, base1, stride=4 * stride_w, stride2=64)
            a1.vadd(2, 1, 1)
        a1.vse(2, base1 + 256, stride=32)
    p1 = a1.finalize(mm1)

    a2 = Assembler("loop")
    for i in range(n_outer):
        for j in range(n_inner):
            a2.vle(1, base2 + i * 64 + j * 4 * stride_w)
            a2.vadd(2, 1, 1)
        a2.vse(2, base2 + 256 + i * 32)
    p2 = a2.finalize(mm2)

    np.testing.assert_array_equal(p1.op, p2.op)
    np.testing.assert_array_equal(p1.addr, p2.addr)
    np.testing.assert_array_equal(p1.vd, p2.vd)
    np.testing.assert_array_equal(p1.vs1, p2.vs1)
