"""Pallas kernels: shape/dtype sweeps in interpret mode vs the pure-jnp
oracles (assignment requirement: per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispersed_gemm, flash_attention, ops, ref, traffic


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("s,d,causal,dtype", [
    (128, 64, False, jnp.float32),
    (128, 64, True, jnp.float32),
    (256, 128, True, jnp.float32),
    (128, 64, True, jnp.bfloat16),
    (256, 64, False, jnp.bfloat16),
])
def test_flash_attention_allclose(s, d, causal, dtype):
    k = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = _rand(k[0], (1, 2, s, d), dtype)
    kk = _rand(k[1], (1, 2, s, d), dtype)
    v = _rand(k[2], (1, 2, s, d), dtype)
    out = flash_attention.flash_attention(
        q, kk, v, causal=causal, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, kk, v, causal=causal)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_gqa_and_cross_lengths():
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k[0], (2, 8, 128, 64), jnp.float32)
    kk = _rand(k[1], (2, 2, 256, 64), jnp.float32)
    v = _rand(k[2], (2, 2, 256, 64), jnp.float32)
    out = ops.flash_attention(q, kk, v, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(q, jnp.repeat(kk, 4, 1), jnp.repeat(v, 4, 1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("m,k,n,w,dtype", [
    (256, 512, 128, 1, jnp.float32),
    (256, 512, 128, 2, jnp.float32),
    (512, 256, 256, 4, jnp.float32),
    (256, 512, 128, 2, jnp.bfloat16),
])
def test_gemm_grouped_allclose(m, k, n, w, dtype):
    a = _rand(jax.random.PRNGKey(m), (m, k), dtype)
    b = _rand(jax.random.PRNGKey(n), (k, n), dtype)
    got = dispersed_gemm.matmul_grouped(a, b, block_m=128, block_k=256,
                                        working_set=w, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,k,n", [(256, 512, 128), (128, 1024, 128)])
def test_gemm_dispersed_allclose(m, k, n):
    a = _rand(jax.random.PRNGKey(1), (m, k), jnp.float32)
    b = _rand(jax.random.PRNGKey(2), (k, n), jnp.float32)
    got = dispersed_gemm.matmul_dispersed(a, b, block_m=128, block_k=256,
                                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               rtol=2e-4, atol=2e-4)


def test_gemm_grouped_bitwise_independent_of_working_set():
    """The architectural result must not depend on the physical working
    set (the paper's core invariant): the grouped kernel accumulates the
    K reduction in the same f32 order for every W, so the outputs are
    bit-identical, not just allclose."""
    a = _rand(jax.random.PRNGKey(3), (256, 512), jnp.float32)
    b = _rand(jax.random.PRNGKey(4), (512, 128), jnp.float32)
    outs = [np.asarray(dispersed_gemm.matmul_grouped(
        a, b, block_m=64, block_k=128, working_set=w, interpret=True))
        for w in (1, 2, 4)]
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


def test_traffic_model_monotone_in_working_set():
    prev = None
    for w in (1, 2, 4, 8, 16, 32):
        t = dispersed_gemm.hbm_traffic_model(4096, 4096, 4096, block_m=128,
                                             block_k=512, working_set=w)
        assert t["grouped"] >= t["ideal"]
        if w >= 4:
            # with a reasonable working set, caching beats HBM round-trips
            assert t["dispersed"] >= t["grouped"]
        if prev is not None:
            assert t["grouped"] <= prev       # more regs => less traffic
        prev = t["grouped"]


def test_traffic_model_closed_forms_pinned():
    """The exact byte counts, term by term — pins the dispersed-B fix
    (B streams once: k*n input-width bytes, no dead nk factor) and the
    f32-width accumulator spill/fill term."""
    m, n, k, bm, bk, bpe = 256, 128, 512, 64, 128, 2
    nm, nk = m // bm, k // bk
    t = dispersed_gemm.hbm_traffic_model(m, n, k, block_m=bm, block_k=bk,
                                         working_set=2, bytes_per_el=bpe)
    assert t["grouped"] == (m * k + (nm // 2) * k * n + m * n) * bpe
    assert t["dispersed"] == (m * k + k * n) * bpe + 2 * m * n * nk * 4
    assert t["ideal"] == (m * k + k * n + m * n) * bpe
    assert t["vmem_acc_bytes"] == 2 * bm * n * 4


def test_traffic_model_rejects_what_the_kernel_rejects():
    """Model legality == kernel legality: a working_set that does not
    divide the m-tile count used to be silently floor-divided into an
    undercounted ``groups``; both sides now raise the same ValueError."""
    a = _rand(jax.random.PRNGKey(5), (256, 512), jnp.float32)
    b = _rand(jax.random.PRNGKey(6), (512, 128), jnp.float32)
    with pytest.raises(ValueError, match="working_set"):
        dispersed_gemm.hbm_traffic_model(256, 128, 512, block_m=64,
                                         block_k=128, working_set=3)
    with pytest.raises(ValueError, match="working_set"):
        dispersed_gemm.matmul_grouped(a, b, block_m=64, block_k=128,
                                      working_set=3, interpret=True)
    with pytest.raises(ValueError, match="working_set"):
        dispersed_gemm.hbm_traffic_model(256, 128, 512, block_m=64,
                                         block_k=128, working_set=0)


@pytest.mark.parametrize("w", [1, 2, 4])
def test_counted_traffic_matches_model_grouped(w):
    kw = dict(block_m=64, block_k=128, working_set=w, bytes_per_el=2)
    model = dispersed_gemm.hbm_traffic_model(256, 128, 512, **kw)
    counted = traffic.count(
        dispersed_gemm.grouped_schedule(256, 128, 512, **kw))
    assert counted["total"] == model["grouped"]


def test_counted_traffic_matches_model_dispersed_and_flash():
    model = dispersed_gemm.hbm_traffic_model(
        256, 128, 512, block_m=64, block_k=128, working_set=1)
    counted = traffic.count(dispersed_gemm.dispersed_schedule(
        256, 128, 512, block_m=64, block_k=128))
    assert counted["total"] == model["dispersed"]
    fm = flash_attention.hbm_traffic_model(
        2, 2, 256, 256, 64, block_q=64, block_k=64)
    fc = traffic.count(flash_attention.flash_schedule(
        2, 2, 256, 256, 64, block_q=64, block_k=64))
    assert fc["total"] == fm["flash"]
    assert fm["flash"] >= fm["ideal"]
    assert fm["materialized"] >= fm["flash"]   # fusing beats spilling S


def test_traffic_model_int8_bytes_per_el():
    """The int8 roofline point's byte accounting: operands at one byte per
    element (bytes_per_el=1) while the dispersed accumulator spill/fill
    stays f32-wide; counted == closed form for all three schedules."""
    m, n, k, bm, bk = 256, 128, 512, 64, 128
    nm, nk = m // bm, k // bk
    kw = dict(block_m=bm, block_k=bk, working_set=2, bytes_per_el=1)
    t = dispersed_gemm.hbm_traffic_model(m, n, k, **kw)
    assert t["grouped"] == m * k + (nm // 2) * k * n + m * n
    assert t["dispersed"] == (m * k + k * n) + 2 * m * n * nk * 4
    assert traffic.count(dispersed_gemm.grouped_schedule(
        m, n, k, **kw))["total"] == t["grouped"]
    assert traffic.count(dispersed_gemm.dispersed_schedule(
        m, n, k, block_m=bm, block_k=bk,
        bytes_per_el=1))["total"] == t["dispersed"]
    fm = flash_attention.hbm_traffic_model(
        1, 2, 128, 128, 64, block_q=64, block_k=64, bytes_per_el=1)
    fc = traffic.count(flash_attention.flash_schedule(
        1, 2, 128, 128, 64, block_q=64, block_k=64, bytes_per_el=1))
    assert fc["total"] == fm["flash"]


def test_kernel_shape_errors_name_the_dimension():
    a = _rand(jax.random.PRNGKey(7), (200, 512), jnp.float32)
    b = _rand(jax.random.PRNGKey(8), (512, 128), jnp.float32)
    with pytest.raises(ValueError, match="m=200"):
        dispersed_gemm.matmul_grouped(a, b, block_m=128, block_k=128,
                                      interpret=True)
    with pytest.raises(ValueError, match="m=200"):
        dispersed_gemm.matmul_dispersed(a, b, block_m=128, block_k=128,
                                        interpret=True)
    bad_b = _rand(jax.random.PRNGKey(9), (256, 128), jnp.float32)
    with pytest.raises(ValueError, match="k=512"):
        dispersed_gemm.matmul_grouped(a[:128], bad_b, interpret=True)
    q = _rand(jax.random.PRNGKey(10), (1, 2, 200, 64), jnp.float32)
    with pytest.raises(ValueError, match="sq=200"):
        flash_attention.flash_attention(q, q, q, block_q=128, block_k=128,
                                        interpret=True)
    with pytest.raises(ValueError, match="multiple"):
        ops.flash_attention(q, q[:, :1][:, [0, 0, 0]], q[:, :3],
                            interpret=True)


@pytest.mark.parametrize("rows,d,dtype", [
    (256, 512, jnp.float32), (128, 1024, jnp.bfloat16),
])
def test_rmsnorm_kernel_allclose(rows, d, dtype):
    from repro.kernels import rmsnorm as rn
    from repro.models import common as mc
    x = _rand(jax.random.PRNGKey(7), (2, rows // 2, d), dtype)
    scale = 1.0 + 0.1 * _rand(jax.random.PRNGKey(8), (d,), jnp.float32)
    got = rn.rmsnorm(x, scale, block_rows=64, interpret=True)
    want = mc.rmsnorm({"scale": scale}, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
