"""repro.cluster: shared-L2 + banked-channel contention model.

Unit-pins the two pure arbiter pieces (round-robin rank order,
exclusive-cumsum queue depths, the LRU L2), then property-tests the fused
cluster engine: makespan monotone in the core count, per-core counters
exactly affine in the traced latencies (with the ``l1_misses - l2_hits``
memory-slope floor), round-robin fairness (no core starves), and the
``repro.api`` planner contract — ONE cluster-engine compile per
(bucket, L1 geometry, cores) plan group.  The N=1 bit-identity pin lives
with the golden counters (``tests/test_golden_counters.py``); the full
paper-size grid of ``benchmarks/cluster_sweep.py`` runs in the slow tier.
"""

import numpy as np
import pytest

from repro import api
from repro.cluster import (CLUSTER_COUNTER_NAMES, ClusterConfig,
                           check_cluster_affine, l2_access, l2_init,
                           queue_rounds, rank_order, simulate_cluster_grid)
from repro.core import policies, simulator

# ---------------------------------------------------------------------------
# Arbiter primitives.
# ---------------------------------------------------------------------------


def test_rank_order_is_a_fair_rotation():
    """Every step's service order is a permutation, and over any N
    consecutive instructions each core holds rank 0 (goes first) exactly
    once — the deterministic no-starvation guarantee."""
    n = 4
    first = []
    for t in range(2 * n):
        order = np.asarray(rank_order(n, t))
        assert sorted(order.tolist()) == list(range(n)), t
        first.append(int(order[0]))
    for core in range(n):
        assert first[:n].count(core) == 1
        assert first[n:].count(core) == 1


def test_queue_rounds_exclusive_cumsum():
    """Rank r waits behind earlier ranks only (own-core misses are already
    serialized inside the core model): reqs [3, 1, 0, 2] on 2 channels
    queue [0, 1, 2, 2] rounds; rank 0 and every single-core cluster get
    exactly zero."""
    q = np.asarray(queue_rounds(np.asarray([3, 1, 0, 2], np.int32), 2))
    assert q.tolist() == [0, 1, 2, 2]
    assert int(queue_rounds(np.asarray([7], np.int32), 1)[0]) == 0
    assert np.asarray(queue_rounds(
        np.asarray([1, 1, 1], np.int32), 8)).tolist() == [0, 0, 0]


def test_l2_access_lru_allocate_and_inactive():
    l2 = l2_init(2, 2)
    clock = 1          # ages stay positive so filled lines beat free ways
    hits = []
    for line in (0, 2, 0, 4, 2):       # all map to set 0 (line % 2 == 0)
        l2, h = l2_access(l2, line, clock, 2)
        hits.append(bool(h))
        clock += 1
    # 0 miss, 2 miss, 0 hit (refreshes age), 4 miss evicting LRU line 2,
    # so 2 misses again
    assert hits == [False, False, True, False, False]
    before = np.asarray(l2)
    l2, h = l2_access(l2, -1, clock, 2)        # inactive: no-op
    assert not bool(h)
    np.testing.assert_array_equal(np.asarray(l2), before)


def test_cluster_config_validation():
    with pytest.raises(ValueError, match="n_cores"):
        ClusterConfig(n_cores=0)
    with pytest.raises(ValueError, match="mem_channels"):
        ClusterConfig(mem_channels=0)
    with pytest.raises(ValueError, match="l2_sets"):
        ClusterConfig(l2_sets=3)
    assert ClusterConfig(l2_sets=256).l2_bytes == 256 * 4 * 32
    assert ClusterConfig.passthrough(4).mem_channels == \
        4 * simulator.NUM_MISS_SITES


# ---------------------------------------------------------------------------
# Engine properties on a real trace.
# ---------------------------------------------------------------------------

_CL = dict(l2_sets=64, l2_ways=2, mem_channels=1)


def _prep():
    from repro import rvv
    b = rvv.BENCHMARKS["gemv"]
    return simulator.prepare(b.build(**b.reduced_params).program)


def _sweep():
    return simulator.SweepConfig(np.asarray([4], np.int32),
                                 np.asarray([policies.LRU], np.int32),
                                 np.zeros(1, bool))


def _machines():
    return simulator.MachineSweep.from_params(
        [simulator.MachineParams(mem_latency=m, l1_sets=8, l1_ways=1)
         for m in (5, 9, 13)])


def _run(n_cores, **kw):
    return simulate_cluster_grid([_prep()], _sweep(), _machines(),
                                 ClusterConfig(n_cores=n_cores, **_CL), **kw)


def test_cluster_makespan_monotone_in_cores():
    """With the shared memory system held fixed, adding lockstep cores can
    only add interference: the cluster makespan is nondecreasing in N at
    every machine point (the per-set LRU stack property — interleaved
    traffic never turns an L2 miss into a hit for the victim)."""
    prev = None
    for n in (1, 2, 4):
        out = _run(n)
        mk = out["cycles"][0, 0]
        if n == 1:
            assert (out["contention_stalls"] == 0).all()
        if prev is not None:
            assert (mk >= prev).all(), (n, mk, prev)
        prev = mk


def test_cluster_per_core_counters_affine_in_latencies():
    """Every core's cycles / stall_cycles / contention_stalls must be
    exactly affine in the traced latencies (l2_hit_cycles is static by
    design) and all decision counters machine-invariant; the mem_latency
    slope floor is l1_misses - l2_hits."""
    out = _run(4, return_per_core=True)
    coeffs = check_cluster_affine(out["per_core"], _machines())
    # (P, C, N, 4) per-core planes; the mem slope must reflect L2 filtering
    assert coeffs["cycles"].shape == (1, 1, 4, 4)
    pc = out["per_core"]
    floor = pc["l1_misses"][0, 0, 0] - pc["l2_hits"][0, 0, 0]
    assert (coeffs["cycles"][0, 0, :, 3] >= floor).all()
    for k in ("l2_hits", "l2_misses", "l1_misses", "vrf_hits", "spills"):
        v = pc[k]                                   # (P, C, M, N)
        assert (v == v[:, :, :1]).all(), k


def test_cluster_rr_fairness_no_core_starves():
    """The rotating arbiter spreads the queueing cost: at N=4 on one
    channel every core pays some contention, the per-core stall spread
    stays within 1.5x, and per-core completion times within 10% — no core
    is starved by a fixed priority."""
    out = _run(4, return_per_core=True)
    pc = out["per_core"]
    stalls = pc["contention_stalls"][0, 0]          # (M, N)
    assert (stalls > 0).all()
    assert (stalls.max(axis=-1) <= 1.5 * stalls.min(axis=-1)).all()
    cyc = pc["cycles"][0, 0]
    assert (cyc.max(axis=-1) <= 1.1 * cyc.min(axis=-1)).all()


def test_cluster_counter_layout():
    out = _run(2)
    assert CLUSTER_COUNTER_NAMES[:len(simulator.COUNTER_NAMES)] == \
        simulator.COUNTER_NAMES
    for k in CLUSTER_COUNTER_NAMES + ("core_cycles_min", "core_cycles_max",
                                      "core_cycles_sum"):
        assert out[k].shape == (1, 1, 3), k
    assert (out["core_cycles_min"] <= out["core_cycles_max"]).all()
    assert (out["cycles"] == out["core_cycles_max"]).all()


# ---------------------------------------------------------------------------
# The api.Session planner contract.
# ---------------------------------------------------------------------------


def test_session_compiles_once_per_cluster_plan_group():
    """The acceptance pin: a cluster sweep is ONE engine call per (bucket,
    L1 geometry, cores) plan group, each its own compile (ClusterConfig and
    geometry are jit statics; the latency grid rides traced inside)."""
    ses = api.Session(batch_programs=False)
    # A cluster shape no other test uses, so the process-level jit cache
    # cannot hide the compiles this sweep must trigger.
    cl = ClusterConfig(l2_sets=32, l2_ways=3, mem_channels=3)
    sweep = api.Sweep(
        kernels=("gemv",), capacity=(3, 5),
        l1_geometry=(api.L1Geometry.from_kbytes(4),
                     api.L1Geometry.from_kbytes(16)),
        cores=(1, 2), cluster=cl, kernel_params="reduced", fold=False)
    res = ses.run(sweep)
    plan = res.meta["plan"]
    groups = {(g["l1_geometry"], g["bucket"], g["cores"]) for g in plan}
    assert len(plan) == len(groups) == 4      # 2 geometries x 1 bucket x 2 N
    assert res.meta["compiles"] == len(groups)
    assert res.meta["dispatches"] == len(plan)
    assert all("cores" in g for g in plan)
    assert res.axis("cores").values == (1, 2)
    assert res.meta["cluster"]["l2_bytes"] == cl.l2_bytes
    # N=1 slice of the cluster grid == the plain single-core sweep
    single = ses.run(api.Sweep(
        kernels=("gemv",), capacity=(3, 5),
        l1_geometry=(api.L1Geometry.from_kbytes(4),
                     api.L1Geometry.from_kbytes(16)),
        kernel_params="reduced", fold=False))
    np.testing.assert_array_equal(
        res.data["cycles"][:, :, :, :, :, 0], single.data["cycles"])


# ---------------------------------------------------------------------------
# The paper-size benchmark grid (slow tier).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cluster_sweep_full_grid():
    from benchmarks import cluster_sweep
    rows = cluster_sweep.run()
    want = (len(cluster_sweep.KERNELS) * len(cluster_sweep.CAPS)
            * len(cluster_sweep.L1_KBYTES) * len(cluster_sweep.CORES))
    assert len(rows) == want
    extra = cluster_sweep.json_extra()
    # One compile per planned (bucket, geometry, cores) group; the shared
    # L2 legitimately breaks some fold certificates, and each failing
    # (kernel, cores) point triggers at most one unfolded refine call.
    refine_cap = len(cluster_sweep.KERNELS) * len(cluster_sweep.CORES)
    assert extra["plan_groups"] <= extra["compiles"] <= \
        extra["plan_groups"] + refine_cap
    for name in cluster_sweep.KERNELS:
        front = extra["iso_budget_front"][name]
        assert front
        budgets = [r["sram_budget_bytes"] for r in front]
        assert budgets == sorted(budgets)
