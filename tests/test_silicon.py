"""The silicon macro-model layer: flop-backend bit-identity with the
legacy closed forms (the SRAM_AU_PER_BIT regression pin), macro-model
laws (monotonicity, table anchors, registry discipline), the N-objective
pareto (old-vs-new equality, 2-obj fronts inside 3-obj projections), and
the DSE driver's front/baseline/winner-flip contract.
"""

import numpy as np
import pytest

from repro import api, metrics, silicon
from repro.core import costmodel, policies

# ---------------------------------------------------------------------------
# Toy grids (test_metrics.toy_result, plus L1-geometry / cores axes so the
# macro models have something to vary over).
# ---------------------------------------------------------------------------

GEOMETRIES = tuple(api.L1Geometry.from_kbytes(kb) for kb in (4, 8, 16))


def toy_result(cores=None) -> api.SweepResult:
    axes = [
        api.Axis("kernel", ("a", "b")),
        api.Axis("capacity", (8, 32)),
        api.Axis("policy", (policies.FIFO,)),
        api.Axis("alloc_no_fetch", (False,)),
        api.Axis("l1_geometry", GEOMETRIES),
        api.Axis("mem_latency", (5,)),
        api.Axis("l1_hit_cycles", (0,)),
        api.Axis("uop_hit_cycles", (1,)),
    ]
    meta = dict(kernel_params="paper")
    if cores is not None:
        axes.append(api.Axis("cores", tuple(cores)))
        meta["cluster"] = dict(l2_sets=256, l2_ways=4,
                               l2_bytes=256 * 4 * 32, mem_channels=2,
                               l2_hit_cycles=2)
    axes = tuple(axes)
    shape = tuple(len(a) for a in axes)

    def grid(a_vals, b_vals):
        base = np.asarray([a_vals, b_vals], np.int64)
        ext = base.reshape((2, 2) + (1,) * (len(shape) - 2))
        return (ext * np.ones(shape, np.int64))

    data = dict(
        cycles=grid([200, 100], [400, 400]),
        stall_cycles=grid([50, 0], [100, 100]),
        spills=grid([4, 0], [8, 0]),
        fills=grid([6, 0], [2, 0]),
        l1_hits=grid([10, 10], [20, 20]),
        l1_misses=grid([2, 2], [4, 4]),
        reg_reads=grid([30, 30], [60, 60]),
        reg_writes=grid([10, 10], [20, 20]),
        mem_reads=grid([2, 2], [4, 4]),
        mem_writes=grid([1, 1], [2, 2]),
        vrf_hits=grid([90, 100], [180, 200]),
        vrf_misses=grid([10, 0], [20, 0]),
    )
    data["hit_rate"] = data["vrf_hits"] / (data["vrf_hits"]
                                           + data["vrf_misses"])
    data["event_scale"] = np.full(shape, 1.0)
    data["fold_exact"] = np.ones(shape, bool)
    return api.SweepResult(axes, data, meta)


WORDS = np.array([64, 128, 256, 512, 1024, 4096])   # 2 KB .. 128 KB lines
BITS = 256


# ---------------------------------------------------------------------------
# Satellite 1: the flop backend IS the legacy constant (bit-identity pins).
# ---------------------------------------------------------------------------


def test_flop_l1_sram_area_bit_identical():
    sets = np.array([[64], [128], [256], [512]])
    ways = np.array([[1, 2, 4, 8]])
    legacy = costmodel.l1_sram_area(sets, ways)
    for macro in ("flop", silicon.get_macro_model("flop")):
        np.testing.assert_array_equal(
            costmodel.l1_sram_area(sets, ways, macro=macro), legacy)


def test_flop_area_with_l1_grid_bit_identical():
    res = toy_result()
    legacy = res.derive("area_with_l1")
    flop = res.derive("area_with_l1", macro_model="flop", out="a2")
    sil = res.derive("silicon_area", macro_model="flop", out="a3")
    sil_default = res.derive("silicon_area", out="a4")   # flop is default
    np.testing.assert_array_equal(flop.data["a2"],
                                  legacy.data["area_with_l1"])
    np.testing.assert_array_equal(sil.data["a3"],
                                  legacy.data["area_with_l1"])
    np.testing.assert_array_equal(sil_default.data["a4"],
                                  legacy.data["area_with_l1"])


def test_flop_cluster_area_grid_bit_identical():
    res = toy_result(cores=(1, 2, 4))
    legacy = res.derive("cluster_area")
    flop = res.derive("cluster_area", macro_model="flop", out="c2")
    sil = res.derive("silicon_cluster_area", macro_model="flop", out="c3")
    np.testing.assert_array_equal(flop.data["c2"],
                                  legacy.data["cluster_area"])
    np.testing.assert_array_equal(sil.data["c3"],
                                  legacy.data["cluster_area"])


def test_flop_energy_is_legacy_energy_plus_l1_leakage():
    """silicon_energy under flop re-prices the flat L1 access energy by
    itself (a no-op) and adds only the L1 macro leakage the core power
    model never charged."""
    res = toy_result()
    r = res.derive("energy").derive("silicon_energy", out="se")
    model = silicon.get_macro_model("flop")
    words = np.asarray([g.sets * g.ways for g in GEOMETRIES]) \
        .reshape((1, 1, 1, 1, 3, 1, 1, 1))
    leak = model.leakage(words, 256) * r.data["scaled_cycles"]
    np.testing.assert_allclose(r.data["se"], r.data["energy"] + leak,
                               rtol=1e-12)


def test_macro_access_energy_flop_is_flat_legacy():
    res = toy_result()
    r = res.derive("l1_macro_access_energy", out="e")
    np.testing.assert_array_equal(
        r.data["e"], np.full(r.shape, costmodel.DEFAULT_POWER.e_l1_access))


# ---------------------------------------------------------------------------
# Satellite 3: macro-model laws.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("flop", "sram6t", "table"))
def test_area_energy_monotone_in_capacity(name):
    m = silicon.get_macro_model(name)
    area = np.asarray(m.area(WORDS, BITS), np.float64)
    energy = np.asarray(m.access_energy(WORDS, BITS), np.float64)
    leak = np.asarray(m.leakage(WORDS, BITS), np.float64)
    assert (np.diff(area) > 0).all(), f"{name} area not increasing"
    assert (np.diff(energy) >= 0).all(), f"{name} energy decreasing"
    assert (np.diff(leak) > 0).all(), f"{name} leakage not increasing"


def test_table_exact_at_anchors():
    m = silicon.get_macro_model("table")
    for total_bits, area, energy, leak in m.points:
        words = int(total_bits) // BITS
        assert float(m.area(words, BITS)) == area
        assert float(m.access_energy(words, BITS)) == energy
        assert float(m.leakage(words, BITS)) == leak
    # outside the anchor range the edge values clamp (no extrapolation)
    lo, hi = m.points[0], m.points[-1]
    assert float(m.area(int(lo[0]) // BITS // 4, BITS)) == lo[1]
    assert float(m.area(int(hi[0]) // BITS * 4, BITS)) == hi[1]


def test_sram6t_curve_shape():
    """Small macros are relatively more expensive than under flop, and the
    overhead ratio shrinks with size (the macro-efficiency curve); access
    energy meets the legacy flat 12.0 at the 16 KB reference macro."""
    flop = silicon.get_macro_model("flop")
    s6t = silicon.get_macro_model("sram6t")
    ratio = np.asarray(s6t.area(WORDS, BITS) / flop.area(WORDS, BITS))
    assert (ratio > 1.0).all()
    assert (np.diff(ratio) < 0).all()
    assert float(s6t.access_energy(512, BITS)) == pytest.approx(12.0,
                                                                abs=0.01)


def test_banks_split_geometry():
    s6t = silicon.get_macro_model("sram6t")
    one = float(s6t.area(1024, BITS, banks=1))
    four = float(s6t.area(1024, BITS, banks=4))
    assert four > one            # 4x periphery on the same bits
    # a bank access touches a quarter of the bits -> cheaper access
    assert float(s6t.access_energy(1024, BITS, banks=4)) \
        < float(s6t.access_energy(1024, BITS, banks=1))
    with pytest.raises(ValueError, match="banks must be >= 1"):
        s6t.area(1024, BITS, banks=0)


def test_registry_discipline():
    assert silicon.macro_model_names() == ["flop", "sram6t", "table"]
    with pytest.raises(ValueError, match="registered twice"):
        silicon.register_macro_model(silicon.FlopMacroModel())
    with pytest.raises(KeyError, match="unknown macro model.*sram6t"):
        silicon.get_macro_model("nope")
    with pytest.raises(TypeError, match="MacroModel"):
        silicon.get_macro_model(42)
    # instances pass through; custom models register and resolve
    from repro.silicon import models as silicon_models
    m = silicon.Sram6TMacroModel(name="test_custom", fixed_au=1.0)
    assert silicon.get_macro_model(m) is m
    silicon.register_macro_model(m)
    try:
        assert silicon.get_macro_model("test_custom") is m
        res = toy_result().derive("l1_macro_area",
                                  macro_model="test_custom", out="a")
        assert np.isfinite(res.data["a"]).all()
    finally:
        silicon_models._MACRO_REGISTRY.pop("test_custom")
    with pytest.raises(ValueError, match=">= 2 anchor"):
        silicon.TableMacroModel("t", ((1024, 1.0, 1.0, 1.0),))
    with pytest.raises(ValueError, match="strictly increasing"):
        silicon.TableMacroModel("t", ((2048, 1.0, 1.0, 1.0),
                                      (1024, 1.0, 1.0, 1.0)))


def test_metrics_lazy_plugin_load():
    """A fresh process that never imports repro.silicon still resolves the
    silicon metrics through the registry's lazy plugin load."""
    import subprocess
    import sys
    code = ("import sys; assert 'repro.silicon' not in sys.modules; "
            "from repro import metrics; "
            "m = metrics.get('silicon_area'); assert m.kind == 'model'; "
            "assert 'silicon_energy' in metrics.catalog()")
    subprocess.run([sys.executable, "-c", code], check=True)


def test_macro_catalog_json_safe():
    import json
    cat = silicon.macro_catalog()
    assert set(cat) == {"flop", "sram6t", "table"}
    ref = costmodel.l1_sram_area(256, 2)     # 512-line (16 KB) reference
    assert cat["flop"]["area_au"] == float(ref)
    json.dumps(cat)


# ---------------------------------------------------------------------------
# Satellite 2: the N-objective pareto — old-vs-new equality + projections.
# ---------------------------------------------------------------------------


def brute_force_pareto(res, x, y, maximize=(), **sel):
    """The original O(n^2) two-objective implementation, verbatim, as the
    reference the vectorized path must reproduce exactly."""
    r = res.select(**sel) if sel else res
    for m in (x, y):
        if m not in r.data:
            r = r.derive(m)
    xs = np.asarray(r.data[x], np.float64)
    ys = np.asarray(r.data[y], np.float64)
    sx = -1.0 if x in maximize else 1.0
    sy = -1.0 if y in maximize else 1.0
    idxs = list(np.ndindex(*r.shape))
    pts = [(sx * xs[i], sy * ys[i]) for i in idxs]
    front = []
    for i, (xi, yi) in enumerate(pts):
        dominated = any(
            (xj <= xi and yj <= yi) and (xj < xi or yj < yi)
            for j, (xj, yj) in enumerate(pts) if j != i)
        if not dominated:
            front.append(i)
    rows = []
    for i in front:
        row = r._labels(idxs[i])
        row[x] = xs[idxs[i]].item()
        row[y] = ys[idxs[i]].item()
        rows.append(row)
    rows.sort(key=lambda rr: (rr[x], rr[y]))
    return rows


def test_pareto_old_vs_new_on_benchmark_grid():
    """Exact old-vs-new front equality on the Pareto-frontier benchmark's
    objectives (area_with_l1 x scaled_cycles over capacity x L1)."""
    res = toy_result()
    old = brute_force_pareto(res, "area_with_l1", "scaled_cycles")
    new = res.pareto("area_with_l1", "scaled_cycles")
    assert old == new
    old_m = brute_force_pareto(res, "area_with_l1", "hit_rate",
                               maximize=("hit_rate",), kernel="a")
    new_m = res.pareto("area_with_l1", "hit_rate",
                       maximize=("hit_rate",), kernel="a")
    assert old_m == new_m


def test_pareto_old_vs_new_randomized():
    rng = np.random.default_rng(7)
    for _ in range(25):
        n, m = int(rng.integers(2, 7)), int(rng.integers(2, 7))
        res = api.SweepResult(
            (api.Axis("i", tuple(range(n))), api.Axis("j", tuple(range(m)))),
            dict(a=rng.integers(0, 5, (n, m)).astype(float),   # many ties
                 b=rng.integers(0, 5, (n, m)).astype(float)),
            {})
        for mx in ((), ("a",), ("b",), ("a", "b")):
            assert brute_force_pareto(res, "a", "b", mx) \
                == res.pareto("a", "b", maximize=mx)


def test_pareto_n_objective_projection():
    """Every 2-objective front is a subset of the 3-objective front's
    projection (on objective-value pairs; a third objective can only save
    points from domination, never dominate new ones)."""
    rng = np.random.default_rng(11)
    for _ in range(10):
        res = api.SweepResult(
            (api.Axis("i", tuple(range(5))), api.Axis("j", tuple(range(6)))),
            {k: rng.integers(0, 5, (5, 6)).astype(float)
             for k in ("a", "b", "c")},
            {})
        f3 = {(r["a"], r["b"], r["c"])
              for r in res.pareto(axes=["a", "b", "c"])}
        for pair in (("a", "b"), ("a", "c"), ("b", "c")):
            f2 = {tuple(r[k] for k in pair) for r in res.pareto(*pair)}
            proj = {tuple(p[("a", "b", "c").index(k)] for k in pair)
                    for p in f3}
            assert f2 <= proj, (pair, f2 - proj)


def test_pareto_n_objective_api():
    res = toy_result()
    two = res.pareto("area_with_l1", "scaled_cycles")
    sugar = res.pareto(axes=["area_with_l1", "scaled_cycles"])
    assert two == sugar
    three = res.pareto(axes=["area_with_l1", "scaled_cycles", "energy"])
    assert len(three) >= len(two)
    for row in three:
        assert {"area_with_l1", "scaled_cycles", "energy"} <= set(row)
    with pytest.raises(TypeError, match="either positional"):
        res.pareto("area_with_l1")
    with pytest.raises(TypeError, match="not both"):
        res.pareto("a", "b", axes=["a", "b"])
    with pytest.raises(ValueError, match="at least 2"):
        res.pareto(axes=["area_with_l1"])
    with pytest.raises(ValueError, match="not objectives"):
        res.pareto(axes=["area_with_l1", "scaled_cycles"],
                   maximize=("nope",))


# ---------------------------------------------------------------------------
# The DSE driver: fronts, provenance, external baseline, winner flip.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dse_extra():
    from benchmarks import dse
    dse.run(names=("dropout",), cores=(1,), kernel_params="paper",
            max_events=4000)
    return dse.json_extra()


def test_dse_front_contract(dse_extra):
    e = dse_extra
    assert e["points"] > 0 and e["compiles"] <= e["plan_groups"]
    for model in ("flop", "sram6t", "table"):
        front = e["fronts"][model]["dropout"]
        interior = [r for r in front if not r.get("external")]
        assert interior, f"{model}: empty front"
        for r in interior:
            # provenance: macro model, geometry, fold cert, plan group
            assert r["macro_model"] == model
            assert {"cores", "capacity", "l1_kb", "l1_geometry",
                    "fold_exact", "plan_group", "bucket",
                    "silicon_cluster_area", "scaled_cycles",
                    "silicon_energy"} <= set(r)
        ext = [r for r in front if r.get("external")]
        assert len(ext) == 1 and ext[0]["source"] == "arXiv:2410.08396"
        assert ext[0]["capacity"] == 16 and not ext[0]["dispersed"]


def test_dse_2obj_front_inside_3obj_projection(dse_extra):
    for model in ("flop", "sram6t", "table"):
        f3 = {(r["silicon_cluster_area"], r["scaled_cycles"])
              for r in dse_extra["fronts"][model]["dropout"]
              if not r.get("external")}
        f2 = {(r["silicon_cluster_area"], r["scaled_cycles"])
              for r in dse_extra["fronts_2d"][model]["dropout"]}
        assert f2 <= f3, (model, f2 - f3)


def test_dse_winner_flip(dse_extra):
    """The acceptance criterion: switching flop -> sram6t changes the
    iso-area winner set (edge-scaled periphery reorders small-L1 full-VRF
    vs big-L1 dispersed configurations)."""
    per = dse_extra["iso_area_winners"]["dropout"]
    assert per["flop"] != per["sram6t"]
    assert per["changed"]


def test_dse_baseline_priced_per_model(dse_extra):
    areas = {m: dse_extra["external_baseline"][m]["dropout"]
             ["silicon_cluster_area"] for m in ("flop", "sram6t", "table")}
    assert areas["sram6t"] != areas["flop"]
    # logic area is shared; only the macro pricing moves the point
    cyc = {m: dse_extra["external_baseline"][m]["dropout"]["scaled_cycles"]
           for m in areas}
    assert len(set(cyc.values())) == 1
