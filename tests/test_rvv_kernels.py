"""Every benchmark kernel (reduced size): interpreter result == reference,
plus dispersed-interpreter semantic equality and FA-2 sanity vs softmax."""

import numpy as np
import pytest

from repro import rvv
from repro.core import interpreter, policies
from repro.rvv import flashattention2


@pytest.mark.parametrize("name", sorted(rvv.BENCHMARKS))
def test_kernel_matches_reference(name):
    b = rvv.BENCHMARKS[name]
    built = b.build(**b.reduced_params)
    res = interpreter.run(built.program)
    rvv.check(built, res.memory)


@pytest.mark.parametrize("name", ["dropout", "gemv", "pathfinder"])
@pytest.mark.parametrize("cap", [3, 5, 8])
def test_dispersed_execution_is_semantics_preserving(name, cap):
    b = rvv.BENCHMARKS[name]
    built = b.build(**b.reduced_params)
    full = interpreter.run(built.program)
    disp = interpreter.run_dispersed(built.program, cap, policies.FIFO)
    np.testing.assert_array_equal(full.memory, disp.memory)


def test_fa2_touches_all_registers_reduced_working_set():
    b = rvv.BENCHMARKS["flashattention2"]
    built = b.build(**b.paper_params)
    assert len(built.program.active_vregs()) == 32


def test_fa2_close_to_true_softmax_attention():
    p = dict(seq=32, d=16, bc=16, seed=3)
    built = flashattention2.build(**p)
    res = interpreter.run(built.program)
    got = built.program.buffer_view(res.memory, "O").reshape(32, 16)
    want = flashattention2.reference_softmax(**p)
    # loose: the kernel uses the squaring exp approximation
    assert np.max(np.abs(got - want)) < 0.25
    assert np.corrcoef(got.ravel(), want.ravel())[0, 1] > 0.99


def test_scalar_costs_positive_and_ordered():
    for name, b in rvv.BENCHMARKS.items():
        c = b.scalar_cost(**b.paper_params)
        assert c.cycles() > 0, name
