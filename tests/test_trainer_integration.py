"""End-to-end trainer: loss decreases, kill->restore->continue matches the
uninterrupted run, microbatching equivalence, compressed-grad path."""

import numpy as np
import pytest

from repro.configs import get
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer


def _tc(tmp, **kw):
    d = dict(seq_len=32, global_batch=4, steps=14, checkpoint_every=7,
             checkpoint_dir=str(tmp), log_every=1000)
    d.update(kw)
    return TrainConfig(**d)


def _oc(**kw):
    d = dict(peak_lr=3e-3, min_lr=3e-4, warmup_steps=2, total_steps=14)
    d.update(kw)
    return OptConfig(**d)


def test_loss_decreases(tmp_path):
    out = Trainer(get("qwen3-8b").reduced(), _tc(tmp_path / "a")).run()
    h = out["history"]
    # default OptConfig has long warmup; use explicit one for the real test
    out = Trainer(get("qwen3-8b").reduced(), _tc(tmp_path / "b"),
                  _oc()).run()
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]


def test_restart_matches_straight_run(tmp_path):
    cfg = get("qwen3-8b").reduced()
    a = Trainer(cfg, _tc(tmp_path / "x"), _oc()).run()
    Trainer(cfg, _tc(tmp_path / "y"), _oc()).run(steps=7)
    b = Trainer(cfg, _tc(tmp_path / "y"), _oc()).run(steps=14)
    assert b["history"][0]["step"] == 7
    np.testing.assert_allclose(b["history"][-1]["loss"],
                               a["history"][-1]["loss"], rtol=1e-4)


def test_microbatch_equivalence(tmp_path):
    cfg = get("phi3-mini-3.8b").reduced()
    a = Trainer(cfg, _tc(tmp_path / "m1", steps=4, microbatches=1),
                _oc(total_steps=4)).run()
    b = Trainer(cfg, _tc(tmp_path / "m2", steps=4, microbatches=2),
                _oc(total_steps=4)).run()
    np.testing.assert_allclose(a["history"][-1]["loss"],
                               b["history"][-1]["loss"], rtol=2e-2)


def test_compressed_gradients_still_learn(tmp_path):
    cfg = get("qwen3-8b").reduced()
    out = Trainer(cfg, _tc(tmp_path / "c", steps=14),
                  _oc(compress_grads=True)).run()
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]
