"""Loop-aware HLO cost analyzer: trip-count multiplication (the XLA
cost_analysis while-loop undercount this corrects is demonstrated here)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost


def _scan_matmul(n, m=256):
    def f(x, ws):
        def step(c, w):
            return c @ w, None
        return jax.lax.scan(step, x, ws)[0]
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, m, m), jnp.float32)
    return jax.jit(f).lower(x, ws).compile()


def test_xla_cost_analysis_undercounts_loops():
    c1 = _scan_matmul(1).cost_analysis()
    c10 = _scan_matmul(10).cost_analysis()
    d = lambda c: (c[0] if isinstance(c, (list, tuple)) else c)["flops"]
    # XLA reports ~1-trip flops for a 10-trip loop (modulo a few counter
    # flops, which vary by jax version) — the undercount we must correct.
    assert d(c10) < 2 * d(c1)


def test_analyzer_multiplies_trip_counts():
    txt = _scan_matmul(10).as_text()
    res = hlo_cost.analyze(txt)
    assert res["dot_flops"] == 10 * 2 * 256 ** 3


def test_analyzer_nested_scans():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, jnp.arange(5))[0], None
        return jax.lax.scan(outer, x, ws)[0]
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["dot_flops"] == 4 * 5 * 2 * 128 ** 3
    assert res["bytes_accessed"] > 0


def test_collective_parse_on_sharded_module():
    import os
    # only meaningful with >1 device; guarded to the forced-host-count env
    if jax.device_count() < 2:
        return
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2), ("x",))
    sh = NamedSharding(mesh, P("x", None))

    def f(a):
        return a.sum()
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f, in_shardings=sh).lower(a).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["collective_bytes"] >= 0
