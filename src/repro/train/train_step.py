"""Training step: microbatched, remat'd, pjit-ready.

``make_train_step(cfg, opt_cfg, microbatches)`` builds a pure function
  (params, opt_state, err, batch) -> (params', opt_state', err', metrics)
that the launcher jits with in/out shardings.  Gradient accumulation over
microbatches overlaps naturally with the compute under XLA; activation
rematerialisation wraps the per-microbatch loss.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.common import shard
from repro.models import common
from repro.optim import adamw

AUX_WEIGHT = 0.01


def cross_entropy(logits, targets):
    """Token-mean CE in f32; logits (B,S,V) sharded over model on V."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return (lse - gold).mean()


def make_loss_fn(cfg):
    model = get_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        loss = cross_entropy(logits, batch["targets"])
        total = loss + AUX_WEIGHT * aux
        return total, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg, opt_cfg: adamw.OptConfig, microbatches: int = 1,
                    remat: bool = False):
    """Per-layer remat is built into the model (scan bodies are
    jax.checkpoint'ed); ``remat=True`` additionally remats the whole loss."""
    loss_fn = make_loss_fn(cfg)
    if remat:
        loss_fn = jax.checkpoint(loss_fn)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, err, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def split(k, x):
                if k == "positions3":       # (3,B,S) -> (mb, 3, B/mb, S)
                    return x.reshape(3, microbatches, -1, x.shape[-1]
                                     ).transpose(1, 0, 2, 3)
                return x.reshape(microbatches, -1, *x.shape[1:])
            mbatches = {k: split(k, v) for k, v in batch.items()}

            def acc_step(carry, mb):
                g_acc, l_acc, a_acc = carry
                (_, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + metrics["loss"],
                        a_acc + metrics["aux"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                acc_step, (zeros, 0.0, 0.0), mbatches)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {"loss": loss_sum / microbatches,
                       "aux": aux_sum / microbatches}

        new_params, new_opt, new_err, stats = adamw.apply_updates(
            opt_cfg, opt_state, params, grads, err)
        metrics.update(stats)
        return new_params, new_opt, new_err, metrics

    return train_step


def shard_batch_specs(cfg, mesh):
    """PartitionSpecs for the input batch (batch dim over pod+data)."""
    from jax.sharding import NamedSharding
    from repro.models.common import spec

    def for_key(k):
        if k == "positions3":
            return NamedSharding(mesh, spec(mesh, None, common.BATCH, None))
        if k in ("vision_embeds", "audio_embeds"):
            return NamedSharding(mesh, spec(mesh, common.BATCH, None, None))
        return NamedSharding(mesh, spec(mesh, common.BATCH, None))
    return for_key
