from repro.train import train_step, trainer
from repro.train.trainer import TrainConfig, Trainer
__all__ = ["train_step", "trainer", "TrainConfig", "Trainer"]
