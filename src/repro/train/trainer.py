"""Training loop: checkpoint/restart, heartbeat, straggler hooks, metrics.

``Trainer.run(steps)`` is restart-safe: it restores the newest complete
checkpoint (params + optimizer + data step) if one exists, so killing the
process at any point and re-running continues bit-identically (the data
pipeline is a pure function of step).  This is the single-process harness of
the multi-pod control loop described in runtime/fault_tolerance.py.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import get_model
from repro.optim import adamw
from repro.runtime.fault_tolerance import (Heartbeat, RestartPolicy,
                                           StragglerPolicy)
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1
    steps: int = 50
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, arch_cfg, train_cfg: TrainConfig,
                 opt_cfg: adamw.OptConfig | None = None):
        self.cfg = arch_cfg
        self.tc = train_cfg
        self.oc = opt_cfg or adamw.OptConfig(
            total_steps=train_cfg.steps,
            warmup_steps=max(1, min(100, train_cfg.steps // 10)))
        self.model = get_model(arch_cfg)
        self.data = SyntheticCorpus(DataConfig(
            vocab_size=arch_cfg.vocab_size, seq_len=train_cfg.seq_len,
            global_batch=train_cfg.global_batch, seed=train_cfg.seed))
        self.ckpt = Checkpointer(train_cfg.checkpoint_dir)
        self.heartbeat = Heartbeat()
        self.stragglers = StragglerPolicy()
        self.restart_policy = RestartPolicy()
        self._step_fn = jax.jit(make_train_step(
            arch_cfg, self.oc, train_cfg.microbatches))

    # ------------------------------------------------------------- state --
    def init_state(self):
        key = jax.random.PRNGKey(self.tc.seed)
        params = self.model.init(key)
        opt = adamw.init_state(params)
        err = (adamw.init_error_feedback(params)
               if self.oc.compress_grads else None)
        return {"params": params, "opt": opt, "err": err}

    def _make_batch(self, step: int):
        b = self.data.batch(step)
        batch = {k: jax.numpy.asarray(v) for k, v in b.items()}
        if self.cfg.positional == "mrope":
            batch["positions3"] = jax.numpy.broadcast_to(
                batch["positions"][None], (3,) + batch["positions"].shape)
        if self.cfg.encoder_decoder:
            # audio frontend stub: deterministic pseudo-embeddings
            bsz = batch["tokens"].shape[0]
            t = np.linspace(0, 1, self.cfg.encoder_seq, dtype=np.float32)
            emb = np.sin(t[:, None] * np.arange(1, self.cfg.d_model + 1)
                         [None] * 0.1).astype(np.float32)
            batch["audio_embeds"] = jax.numpy.asarray(
                np.broadcast_to(emb, (bsz,) + emb.shape)) * 0.05
        return batch

    # --------------------------------------------------------------- run --
    def run(self, steps: int | None = None, state=None) -> dict:
        steps = steps or self.tc.steps
        start = 0
        if state is None:
            state = self.init_state()
            if self.ckpt.latest_step() is not None:
                start, restored = self.ckpt.restore(
                    {"params": state["params"], "opt": state["opt"]})
                state["params"] = restored["params"]
                state["opt"] = restored["opt"]
        history = []
        for step in range(start, steps):
            t0 = time.monotonic()
            batch = self._make_batch(step)
            params, opt, err, metrics = self._step_fn(
                state["params"], state["opt"], state["err"], batch)
            state = {"params": params, "opt": opt, "err": err}
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            metrics["step_time"] = time.monotonic() - t0
            history.append(metrics)
            self.heartbeat.beat(step)
            self.stragglers.observe(self.heartbeat.records)
            if (step + 1) % self.tc.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": state["params"],
                                          "opt": state["opt"]})
            if step % self.tc.log_every == 0:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} "
                      f"gnorm {metrics['grad_norm']:.3f}")
        self.ckpt.wait()
        return {"state": state, "history": history}
