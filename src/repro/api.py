"""One front door for the paper's design-space sweeps.

Every result in the paper — Fig 4's capacity sweep, Table 3's speedups, the
memory-system ablation — is a point grid over the same named axes:

  ``kernel``        benchmark name from the :mod:`repro.rvv` registry
  ``capacity``      physical registers in the compact VRF
  ``policy``        replacement policy (int constant or ``"fifo"``-style name)
  ``alloc_no_fetch``  beyond-paper write-allocate optimisation
  ``l1_geometry``   static L1 shape (:class:`L1Geometry`) — sizes the L1
                    state arrays, so each value is its own compiled engine
  ``cores``         static cluster-size axis (N lockstep dispersion cores
                    behind a shared L2, :mod:`repro.cluster`) — like the
                    geometry, N sizes the engine state, so each value is
                    its own compiled engine; present only when requested
  ``mem_latency`` / ``l1_hit_cycles`` / ``uop_hit_cycles``
                    traced machine-latency axes (never recompile)

A :class:`Sweep` declares values for those axes; a :class:`Session` executes
it.  ``Session.run`` plans the execution: points are grouped into one fused
engine call per (program-shape bucket, L1 geometry) — the static geometry
axis becomes an orchestrated outer loop inside the planner instead of a
hand-rolled loop in user code — and the traced latency grid rides inside
each dispatch.  The result is a :class:`SweepResult` with labeled axes,
per-point counters and per-point ``fold_exact`` certificates, plus
``to_rows()`` / ``select()`` / ``value()`` accessors so suites never do
index arithmetic on raw (P, C, M) arrays again — and the metric algebra
(``derive`` / ``normalize`` / ``pareto``, evaluated by the
:mod:`repro.metrics` registry) so they never hand-roll derived
quantities either.

The Session owns every cache the old module-global benchmark layer held:
built kernels, prepared (expanded + folded) traces, the fold/refine policy,
and compile/dispatch accounting (``compile_count()`` — the probe the
planner tests pin).  Two Sessions share nothing except XLA's process-level
executable cache, which is keyed only on shapes and static geometry.

Legacy entry points (``simulator.simulate_sweep``, the benchmark layer's
``prepared_for(max_events=...)`` truncation) are deprecation shims routed
through this module — see ``docs/api.md`` for the migration table.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import folding, policies, simulator
from repro.core.simulator import (DEFAULT_MACHINE, MachineSweep,
                                  SweepConfig)

__all__ = [
    "L1Geometry", "ConfigPoint", "Axis", "Sweep", "SweepResult", "Session",
    "default_session", "reset_default_session", "sweep_program",
    "REFINE_MAX_ROWS",
]

# A folded trace whose steadiness check fails is re-simulated in full when
# the full trace is affordable; bigger traces keep the (flagged) fold.
REFINE_MAX_ROWS = 400_000


# ---------------------------------------------------------------------------
# Axis value types.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class L1Geometry:
    """Static L1 data-cache shape: ``sets`` x ``ways`` lines of 32 bytes.

    These two fields size the engine's L1 state arrays, so every distinct
    geometry is a separate compiled executable — which is exactly why the
    planner treats this axis as its outer loop rather than a traced one.
    """

    sets: int = 256
    ways: int = 2

    LINE_BYTES = 32

    @classmethod
    def from_kbytes(cls, kbytes: int, ways: int = 2) -> "L1Geometry":
        return cls(kbytes * 1024 // cls.LINE_BYTES // ways, ways)

    @property
    def kbytes(self) -> int:
        return self.sets * self.ways * self.LINE_BYTES // 1024

    def __str__(self) -> str:
        return f"{self.kbytes}KB/{self.ways}w"


@dataclasses.dataclass(frozen=True)
class ConfigPoint:
    """One zipped (capacity, policy, alloc_no_fetch) configuration point,
    for irregular grids the product axes cannot express (e.g. the policy
    headroom study's per-capacity FIFO+no-fetch extra column)."""

    capacity: int
    policy: int = policies.FIFO
    alloc_no_fetch: bool = False


_POLICY_BY_NAME = {v: k for k, v in policies.POLICY_NAMES.items()}


def _policy_id(p) -> int:
    if isinstance(p, str):
        try:
            return _POLICY_BY_NAME[p.lower()]
        except KeyError:
            raise ValueError(
                f"unknown policy {p!r}; available: "
                f"{', '.join(sorted(_POLICY_BY_NAME))}") from None
    return int(p)


def _as_geometry(g) -> L1Geometry:
    if isinstance(g, L1Geometry):
        return g
    if isinstance(g, tuple) and len(g) == 2:
        return L1Geometry(int(g[0]), int(g[1]))
    raise TypeError(
        f"l1_geometry values must be L1Geometry or (sets, ways) tuples, "
        f"got {g!r}")


def _as_config_point(c) -> ConfigPoint:
    if isinstance(c, ConfigPoint):
        return ConfigPoint(int(c.capacity), _policy_id(c.policy),
                           bool(c.alloc_no_fetch))
    if isinstance(c, dict):
        return _as_config_point(ConfigPoint(**c))
    if isinstance(c, (tuple, list)) and 1 <= len(c) <= 3:
        return _as_config_point(ConfigPoint(*c))
    raise TypeError(
        f"config_points entries must be ConfigPoint / (capacity, policy, "
        f"alloc_no_fetch) tuples / dicts, got {c!r}")


def _as_tuple(v) -> tuple:
    if isinstance(v, (str, bytes)):
        return (v,)
    try:
        return tuple(v)
    except TypeError:
        return (v,)


@dataclasses.dataclass(frozen=True)
class Axis:
    """One labeled sweep axis: a name and its ordered point values."""

    name: str
    values: tuple

    def __len__(self) -> int:
        return len(self.values)

    def indices(self, want) -> list[int]:
        """Positions of the requested value(s), normalised per axis type.
        Lists/sets/arrays always multi-select; tuples multi-select too,
        except on the ``config``/``l1_geometry`` axes where a tuple is one
        point."""
        multi = (list, set, np.ndarray)
        if self.name not in ("config", "l1_geometry"):
            multi += (tuple,)
        wants = list(want) if isinstance(want, multi) else [want]
        norm = {"policy": _policy_id, "l1_geometry": _as_geometry,
                "config": _as_config_point}.get(self.name, lambda v: v)
        idx = []
        for w in wants:
            w = norm(w)
            hits = [i for i, v in enumerate(self.values) if v == w]
            if not hits:
                raise ValueError(
                    f"axis {self.name!r} has no point {w!r}; values: "
                    f"{list(self.values)}")
            idx.extend(hits)
        return idx


# ---------------------------------------------------------------------------
# The declarative sweep spec.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A declarative design-space sweep over named axes.

    The config axes (``capacity`` x ``policy`` x ``alloc_no_fetch``) and the
    machine-latency axes (``mem_latency`` x ``l1_hit_cycles`` x
    ``uop_hit_cycles``) form full cartesian products; ``config_points``
    replaces the three config axes with one zipped ``config`` axis for
    irregular grids.  ``l1_geometry`` is the static outer axis the planner
    orchestrates (one engine build per geometry).

    ``kernel_params`` selects the build size: ``"paper"`` (default),
    ``"reduced"``, or a dict of build kwargs applied to every kernel.
    ``fold=None`` defers to the Session's fold policy.  ``max_events`` is
    the legacy truncation budget (forces ``fold`` off) — kept as an explicit
    escape hatch for smoke runs; prefer folding.

    ``network`` names models from :mod:`repro.configs.registry`: each is
    lowered through :mod:`repro.bridge` (layer shapes -> deduplicated
    ``net:*`` kernels, registered on first use) and the union of lowered
    kernels joins the ``kernel`` axis — so one ``Sweep(network=(...,))``
    plans a whole model mix as a single planned run.  The lowered
    per-layer records ride on the result's ``meta["networks"]``;
    :func:`repro.bridge.network_report` folds per-kernel counters back
    into per-model totals.

    ``cores`` turns the sweep into a **cluster** sweep
    (:mod:`repro.cluster`): each value N runs every point on N lockstep
    dispersion cores behind the shared memory system described by
    ``cluster`` (a :class:`repro.cluster.ClusterConfig` template whose
    ``n_cores`` is overridden per axis point; ``None`` means no shared
    L2, one memory channel).  Like ``l1_geometry``, ``cores`` is static —
    the planner compiles one engine per (bucket, geometry, cores) group —
    and the result grid gains a ``cores`` axis (after ``l1_geometry``)
    plus the cluster counters (``contention_stalls``, ``l2_hits``,
    ``l2_misses``, ``core_cycles_min/max/sum``); ``cycles`` becomes the
    cluster makespan.  Single-core sweeps (``cores=(1,)`` and no
    ``cluster``) are untouched — no ``cores`` axis, no cluster counters.
    """

    kernels: tuple[str, ...] = ()
    capacity: tuple[int, ...] = (8,)
    policy: tuple[int, ...] = (policies.FIFO,)
    alloc_no_fetch: tuple[bool, ...] = (False,)
    config_points: tuple[ConfigPoint, ...] | None = None
    mem_latency: tuple[int, ...] = (DEFAULT_MACHINE.mem_latency,)
    l1_hit_cycles: tuple[int, ...] = (DEFAULT_MACHINE.l1_hit_cycles,)
    uop_hit_cycles: tuple[int, ...] = (DEFAULT_MACHINE.uop_hit_cycles,)
    l1_geometry: tuple[L1Geometry, ...] = (
        L1Geometry(DEFAULT_MACHINE.l1_sets, DEFAULT_MACHINE.l1_ways),)
    kernel_params: str | dict = "paper"
    fold: bool | None = None
    max_events: int | None = None
    network: tuple[str, ...] = ()
    cores: tuple[int, ...] = (1,)
    cluster: object | None = None     # repro.cluster.ClusterConfig template

    def __post_init__(self):
        fix = object.__setattr__
        fix(self, "network",
            tuple(_as_tuple(self.network)) if self.network else ())
        kernels = list(_as_tuple(self.kernels))
        lowered = ()
        if self.network:
            from repro.bridge import lower_network
            lowered = tuple(lower_network(m) for m in self.network)
            for net in lowered:
                kernels += [k for k in net.kernels if k not in kernels]
        fix(self, "_lowered", lowered)    # companion record, not a field
        fix(self, "kernels", tuple(kernels))
        if not self.kernels:
            raise ValueError("Sweep needs at least one kernel name")
        fix(self, "capacity", tuple(int(c) for c in _as_tuple(self.capacity)))
        fix(self, "policy",
            tuple(_policy_id(p) for p in _as_tuple(self.policy)))
        fix(self, "alloc_no_fetch",
            tuple(bool(a) for a in _as_tuple(self.alloc_no_fetch)))
        if self.config_points is not None:
            fix(self, "config_points",
                tuple(_as_config_point(c)
                      for c in _as_tuple(self.config_points)))
        fix(self, "mem_latency",
            tuple(int(m) for m in _as_tuple(self.mem_latency)))
        fix(self, "l1_hit_cycles",
            tuple(int(m) for m in _as_tuple(self.l1_hit_cycles)))
        fix(self, "uop_hit_cycles",
            tuple(int(m) for m in _as_tuple(self.uop_hit_cycles)))
        fix(self, "l1_geometry",
            tuple(_as_geometry(g) for g in _as_tuple(self.l1_geometry)))
        fix(self, "cores", tuple(int(n) for n in _as_tuple(self.cores)))
        if any(n < 1 for n in self.cores):
            raise ValueError(f"cores values must be >= 1, got {self.cores}")
        if self.cluster is not None:
            from repro.cluster import ClusterConfig
            if not isinstance(self.cluster, ClusterConfig):
                raise TypeError(
                    f"cluster must be a repro.cluster.ClusterConfig, "
                    f"got {self.cluster!r}")

    @property
    def is_cluster(self) -> bool:
        """True when this sweep runs the cluster engine (a non-trivial
        ``cores`` axis or an explicit shared-memory ``cluster`` template)."""
        return self.cores != (1,) or self.cluster is not None

    def cluster_config(self, n_cores: int):
        """The :class:`repro.cluster.ClusterConfig` for one ``cores`` point:
        the ``cluster`` template with its ``n_cores`` overridden (default
        template: no shared L2, one memory channel)."""
        from repro.cluster import ClusterConfig
        base = self.cluster if self.cluster is not None else ClusterConfig()
        return dataclasses.replace(base, n_cores=int(n_cores))

    # -- derived engine inputs -------------------------------------------

    def config(self) -> SweepConfig:
        """The flattened (C,) config axis the engine vmaps over."""
        if self.config_points is not None:
            return SweepConfig(
                np.asarray([c.capacity for c in self.config_points],
                           np.int32),
                np.asarray([c.policy for c in self.config_points], np.int32),
                np.asarray([c.alloc_no_fetch for c in self.config_points],
                           bool))
        return SweepConfig.product(self.capacity, self.policy,
                                   self.alloc_no_fetch)

    def machine_sweep(self, geometry: L1Geometry) -> MachineSweep:
        """The traced (M,) latency grid bound to one static geometry."""
        return MachineSweep.product(
            self.mem_latency, self.l1_hit_cycles, self.uop_hit_cycles,
            l1_sets=geometry.sets, l1_ways=geometry.ways)

    def axes(self) -> tuple[Axis, ...]:
        """The labeled result axes, in canonical (row-major) order."""
        if self.config_points is not None:
            cfg_axes = (Axis("config", self.config_points),)
        else:
            cfg_axes = (Axis("capacity", self.capacity),
                        Axis("policy", self.policy),
                        Axis("alloc_no_fetch", self.alloc_no_fetch))
        core_axes = (Axis("cores", self.cores),) if self.is_cluster else ()
        return ((Axis("kernel", self.kernels),) + cfg_axes
                + (Axis("l1_geometry", self.l1_geometry),) + core_axes
                + (Axis("mem_latency", self.mem_latency),
                   Axis("l1_hit_cycles", self.l1_hit_cycles),
                   Axis("uop_hit_cycles", self.uop_hit_cycles)))


# ---------------------------------------------------------------------------
# The labeled result grid.
# ---------------------------------------------------------------------------


_CONFIG_FIELDS = ("capacity", "policy", "alloc_no_fetch")
# Row-field name -> L1Geometry attribute, shared with repro.metrics'
# axis_grid so label expansion and metric grids can never disagree.
_GEOMETRY_FIELDS = {"l1_sets": "sets", "l1_ways": "ways", "l1_kb": "kbytes"}


@dataclasses.dataclass
class SweepResult:
    """Counter grids over labeled axes (see :meth:`Sweep.axes` for order).

    ``data`` maps counter name -> ndarray shaped like the axes; alongside
    the raw :data:`simulator.COUNTER_NAMES` it carries ``hit_rate``,
    ``event_scale`` and the per-point ``fold_exact`` certificate.
    ``fold_exact`` certifies the periodic-fold extrapolation only — it is
    vacuously True for unfolded points, including ``max_events``-truncated
    smoke runs, whose scaled-prefix approximation is flagged by
    ``event_scale > 1`` instead.  ``meta`` records the execution plan:
    dispatch groups, compile/dispatch counts and point totals.
    """

    axes: tuple[Axis, ...]
    data: dict[str, np.ndarray]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(a) for a in self.axes)

    @classmethod
    def from_table(cls, axes: dict, rows: list[dict], values=None,
                   meta: dict | None = None) -> "SweepResult":
        """Assemble a labeled grid from flat result rows.

        ``axes`` is an ordered {name: values} mapping; every row must carry
        each axis name (its value locating the row on the grid) plus the
        measured fields.  ``values`` names the fields to grid (default:
        every non-axis key of the first row).  Missing grid points read
        NaN.  This is how non-simulator sweeps (e.g. the serving SLO
        benchmark) ride the same ``select``/``pareto``/``derive`` surface
        as the cVRF grids.
        """
        ax = tuple(Axis(n, tuple(_as_tuple(v))) for n, v in axes.items())
        if not rows:
            raise ValueError("from_table needs at least one row")
        names = [a.name for a in ax]
        if values is None:
            values = [k for k in rows[0] if k not in names]
        shape = tuple(len(a) for a in ax)
        data = {k: np.full(shape, np.nan) for k in values}
        lookup = [{v: i for i, v in enumerate(a.values)} for a in ax]
        for row in rows:
            try:
                idx = tuple(lk[row[a.name]]
                            for a, lk in zip(ax, lookup))
            except KeyError as e:
                raise ValueError(
                    f"row {row!r} has no grid point for axis value "
                    f"{e.args[0]!r}") from None
            for k in values:
                data[k][idx] = float(row[k])
        return cls(ax, data, meta if meta is not None else {})

    def keys(self):
        return self.data.keys()

    def __getitem__(self, counter: str) -> np.ndarray:
        return self.data[counter]

    def axis(self, name: str) -> Axis:
        for a in self.axes:
            if a.name == name:
                return a
        raise KeyError(f"no axis {name!r}; axes: "
                       f"{[a.name for a in self.axes]}")

    # -- accessors --------------------------------------------------------

    def _resolve(self, key, want) -> tuple[int, list[int]]:
        names = [a.name for a in self.axes]
        if key in names:
            ai = names.index(key)
            return ai, self.axes[ai].indices(want)
        if key in _CONFIG_FIELDS and "config" in names:
            ai = names.index("config")
            axis = self.axes[ai]
            wants = list(want) if isinstance(
                want, (list, tuple, set, np.ndarray)) else [want]
            if key == "policy":
                wants = [_policy_id(w) for w in wants]
            idx = [i for i, c in enumerate(axis.values)
                   if getattr(c, key) in wants]
            if not idx:
                raise ValueError(
                    f"no config point with {key}={want!r}; points: "
                    f"{list(axis.values)}")
            return ai, idx
        raise KeyError(f"unknown axis {key!r}; axes: {names}")

    def select(self, **sel) -> "SweepResult":
        """Filter axes by value (scalar keeps a length-1 axis; a list keeps
        the listed points).  With a zipped ``config`` axis, ``capacity`` /
        ``policy`` / ``alloc_no_fetch`` filter by field.  Views share the
        sweep's ``meta``, so ``derive`` on any view records into the same
        execution history entry."""
        r = self
        for key, want in sel.items():
            ai, idx = r._resolve(key, want)       # against the narrowed axes
            axes = list(r.axes)
            axes[ai] = Axis(axes[ai].name,
                            tuple(axes[ai].values[i] for i in idx))
            r = SweepResult(
                tuple(axes),
                {k: np.take(v, idx, axis=ai) for k, v in r.data.items()},
                self.meta)
        return r

    def value(self, counter: str, **sel):
        """The single scalar at a fully determined point."""
        r = self.select(**sel) if sel else self
        arr = r.data[counter]
        if arr.size != 1:
            raise ValueError(
                f"selection leaves {arr.size} points for {counter!r} "
                f"(shape {r.shape}); pin every multi-valued axis")
        return arr.reshape(())[()].item()

    def array(self, counter: str, **sel) -> np.ndarray:
        """Counter values for a selection, singleton axes squeezed away."""
        r = self.select(**sel) if sel else self
        return np.squeeze(r.data[counter])

    def to_grid(self, **sel) -> dict[str, np.ndarray]:
        """The legacy (P, C, M) engine view — kernels x flattened configs x
        flattened machine-latency points — for one L1 geometry (select a
        geometry first when the sweep has several).  This is the shape
        :func:`repro.core.costmodel.check_machine_affine` consumes."""
        r = self.select(**sel) if sel else self
        geo = r.axis("l1_geometry")
        if len(geo) != 1:
            raise ValueError(
                "to_grid needs a single L1 geometry; select one of "
                f"{list(geo.values)} first")
        p = len(r.axes[0])
        m = math.prod(len(r.axis(n)) for n in
                      ("mem_latency", "l1_hit_cycles", "uop_hit_cycles"))
        c = math.prod(len(a) for a in r.axes) // (p * m)
        return {k: np.ascontiguousarray(v).reshape(p, c, m)
                for k, v in r.data.items()}

    def _labels(self, idx) -> dict:
        """Axis labels of one grid point, expanded to scalar fields."""
        row = {}
        for a, i in zip(self.axes, idx):
            v = a.values[i]
            if a.name == "config":
                row.update(capacity=v.capacity, policy=v.policy,
                           alloc_no_fetch=v.alloc_no_fetch)
                row["policy_name"] = policies.POLICY_NAMES[v.policy]
            elif a.name == "policy":
                row["policy"] = v
                row["policy_name"] = policies.POLICY_NAMES[v]
            elif a.name == "l1_geometry":
                row["l1_geometry"] = str(v)
                row.update({f: getattr(v, attr)
                            for f, attr in _GEOMETRY_FIELDS.items()})
            else:
                row[a.name] = v
        return row

    def to_rows(self, counters=None) -> list[dict]:
        """One dict per grid point: every axis label (config points and
        geometries expanded into scalar fields) plus the counters."""
        counters = list(counters) if counters is not None \
            else list(self.data)
        rows = []
        for idx in np.ndindex(*self.shape):
            row = self._labels(idx)
            for k in counters:
                row[k] = self.data[k][idx].item()
            rows.append(row)
        return rows

    def quantile(self, q: float, over: str) -> "SweepResult":
        """Collapse the ``over`` axis to its q-th percentile (0..100),
        counter by counter — e.g. ``result.quantile(99, over="seed")``
        turns a per-seed grid into a p99 grid.  The collapsed axis is
        removed from the result."""
        names = [a.name for a in self.axes]
        if over not in names:
            raise KeyError(f"no axis {over!r}; axes: {names}")
        ai = names.index(over)
        axes = tuple(a for a in self.axes if a.name != over)
        data = {k: np.percentile(v, q, axis=ai)
                for k, v in self.data.items()}
        return SweepResult(axes, data, self.meta)

    # -- the metric algebra (repro.metrics evaluates; this owns the axes) --

    def _baseline_view(self, baseline: dict) -> "SweepResult":
        """The baseline-aligned view of this grid, broadcastable against
        it: every product axis named in ``baseline`` is pinned to exactly
        one point (kept as a length-1 axis); on a zipped ``config`` axis,
        ``capacity``/``policy``/``alloc_no_fetch`` keys pin *fields* and
        each config point is aligned to the point sharing its remaining
        fields (e.g. ``baseline=dict(policy="fifo")`` maps every (cap,
        pol) point to (cap, FIFO))."""
        if not isinstance(baseline, dict) or not baseline:
            raise TypeError("baseline must be a non-empty dict of axis "
                            "selections, e.g. dict(capacity=32)")
        names = [a.name for a in self.axes]
        r = self
        pins = {}
        for key, want in baseline.items():
            if key in names:
                r = r.select(**{key: want})
                if len(r.axis(key)) != 1:
                    raise ValueError(
                        f"baseline {key}={want!r} selects "
                        f"{len(r.axis(key))} points; pin exactly one")
            elif key in _CONFIG_FIELDS and "config" in names:
                pins[key] = _policy_id(want) if key == "policy" else want
            else:
                raise KeyError(
                    f"unknown baseline axis {key!r}; axes: {names}")
        if pins:
            ai = names.index("config")
            pts = r.axis("config").values
            first = {}
            for j, c in enumerate(pts):
                first.setdefault((c.capacity, c.policy, c.alloc_no_fetch),
                                 j)
            idx = []
            for c in pts:
                tgt = tuple(pins.get(f, getattr(c, f))
                            for f in _CONFIG_FIELDS)
                if tgt not in first:
                    raise ValueError(
                        f"no baseline config point "
                        f"{dict(zip(_CONFIG_FIELDS, tgt))} to align "
                        f"{c} against")
                idx.append(first[tgt])
            axes = list(r.axes)
            axes[ai] = Axis("config", tuple(pts[j] for j in idx))
            r = SweepResult(
                tuple(axes),
                {k: np.take(v, idx, axis=ai) for k, v in r.data.items()},
                self.meta)
        return r

    def derive(self, metric, baseline: dict | None = None,
               out: str | None = None, **params) -> "SweepResult":
        """Evaluate a registered :mod:`repro.metrics` metric over the whole
        grid and return a new result carrying it as an extra labeled
        counter (under ``out`` or the metric's name).  Relational metrics
        require ``baseline=`` (an axis-selection dict); extra keyword
        arguments are metric parameters.  Sub-metrics the evaluation pulls
        in via ``ctx.counter`` ride along in the returned data.  Deriving
        is pure counter algebra — it never compiles or dispatches."""
        from repro import metrics as _metrics
        m = _metrics.get(metric)
        r = SweepResult(self.axes, dict(self.data), self.meta)
        arr = _metrics.evaluate(r, m, baseline=baseline, params=params)
        r.data[out or m.name] = np.broadcast_to(
            np.asarray(arr), self.shape).copy()
        record = dict(metric=m.name, kind=m.kind, out=out or m.name)
        if baseline is not None:
            record["baseline"] = {k: str(v) for k, v in baseline.items()}
        if params:
            record["params"] = {k: str(v) for k, v in params.items()}
        derived = self.meta.setdefault("derived", [])
        if record not in derived:
            derived.append(record)
        return r

    def normalize(self, counter: str, baseline: dict) -> "SweepResult":
        """Return a copy with ``counter`` divided by its value at the
        ``baseline`` selection (broadcast; the baseline points read 1.0).
        Other counters are untouched."""
        base = self._baseline_view(baseline)
        r = SweepResult(self.axes, dict(self.data), self.meta)
        r.data[counter] = self.data[counter] / base.data[counter]
        return r

    def pareto(self, x: str | None = None, y: str | None = None,
               axes: list | tuple | None = None, maximize: tuple = (),
               **sel) -> list[dict]:
        """The maximal (non-dominated) front over N objectives across every
        point of the (optionally ``select``-narrowed) grid.

        Objectives come either as the classic two-objective sugar
        ``pareto(x, y)`` or as ``pareto(axes=["area", "cycles",
        "energy"])`` — the two forms are exclusive and ``pareto(x, y)``
        is exactly ``pareto(axes=[x, y])``.  Every objective is minimized
        unless named in ``maximize``; objectives may be counters or
        registered non-relational metrics (derived on demand).  A point is
        dominated when some other point is no worse on every objective and
        strictly better on at least one; exact ties on all objectives keep
        both points (so duplicates survive, as in the original
        two-objective implementation).

        Dominance is resolved with a lexicographic sort + incremental
        front (only lexicographically earlier points can dominate, and any
        dominator is itself dominated only by earlier front members), so
        the scan is one vectorized comparison per point against the
        growing front instead of the old all-pairs Python loop.

        Returns the non-dominated points as label rows (axis labels
        expanded, plus the objective values), sorted ascending by the
        tuple of raw objective values (for two objectives: ascending
        ``x``, then ``y`` — the original ordering).
        """
        if axes is None:
            if x is None or y is None:
                raise TypeError(
                    "pareto needs either positional x and y or "
                    "axes=[obj1, obj2, ...]")
            objectives = [x, y]
        else:
            if x is not None or y is not None:
                raise TypeError("pass either (x, y) or axes=, not both")
            objectives = list(axes)
        if len(objectives) < 2:
            raise ValueError(
                f"pareto needs at least 2 objectives, got {objectives!r}")
        if isinstance(maximize, str):
            maximize = (maximize,)
        unknown = sorted(set(maximize) - set(objectives))
        if unknown:
            raise ValueError(
                f"maximize names {unknown} are not objectives "
                f"{objectives}")
        r = self.select(**sel) if sel else self
        for m in objectives:
            if m not in r.data:
                r = r.derive(m)
        vals = np.stack([np.asarray(r.data[m], np.float64).ravel()
                         for m in objectives])          # (N_obj, K) raw
        signs = np.array([-1.0 if m in maximize else 1.0
                          for m in objectives])
        obj = vals * signs[:, None]                     # minimize all
        npts = obj.shape[1]
        # lexsort's last key is primary -> sort by obj0, then obj1, ...
        order = np.lexsort(obj[::-1])
        fv = np.empty((npts, len(objectives)))
        nf = 0
        front = []
        for k in order:
            p = obj[:, k]
            if nf:
                le = (fv[:nf] <= p).all(axis=1)
                lt = (fv[:nf] < p).any(axis=1)
                if bool(np.any(le & lt)):
                    continue
            fv[nf] = p
            nf += 1
            front.append(int(k))
        rows = []
        for k in front:
            idx = tuple(int(v) for v in np.unravel_index(k, r.shape))
            row = r._labels(idx)
            for oi, m in enumerate(objectives):
                row[m] = vals[oi, k].item()
            rows.append(row)
        rows.sort(key=lambda rr: tuple(rr[m] for m in objectives))
        return rows


# ---------------------------------------------------------------------------
# The session: cache owner + execution planner.
# ---------------------------------------------------------------------------


class Session:
    """Owns every sweep-side cache and executes :class:`Sweep` specs.

    * *built* kernels, keyed (name, build params);
    * *prepared* traces (expanded + folded / truncated), keyed (name,
      params, fold, max_events, fold warm-up — a function of the static L1
      geometry only);
    * the fold / refine policy (``refine`` transparently re-simulates
      uncertified folds without folding when the full trace is affordable);
    * compile / dispatch accounting for every engine call it issued
      (``compile_count()`` — one compile per (shape bucket, L1 geometry)).

    Compiled executables live in XLA's process-level jit cache (keyed only
    on shapes and static geometry), so Sessions never recompile each
    other's buckets — but they share no Python state: two Sessions build
    and prepare independently, and dropping one frees its traces.

    ``batch_programs=None`` picks the backend default: per-program
    dispatches on CPU (vmapped lanes execute serially there, and per-trace
    padding stays small), one fused dispatch per planner group elsewhere.
    """

    def __init__(self, fold: bool = True, refine: bool = True,
                 refine_max_rows: int = REFINE_MAX_ROWS,
                 batch_programs: bool | None = None):
        self.fold = fold
        self.refine = refine
        self.refine_max_rows = refine_max_rows
        if batch_programs is None:
            import jax
            batch_programs = jax.default_backend() != "cpu"
        self.batch_programs = batch_programs
        self.history: list[dict] = []
        self._built: dict = {}
        self._prepared: dict = {}
        self._compiles = 0
        self._dispatches = 0

    # -- caches -----------------------------------------------------------

    @staticmethod
    def _build_params(bench, params):
        if params == "paper":
            return dict(bench.paper_params)
        if params == "reduced":
            return dict(bench.reduced_params)
        if isinstance(params, dict):
            return dict(params)
        raise ValueError(
            f"kernel_params must be 'paper', 'reduced' or a dict of build "
            f"kwargs, got {params!r}")

    def built(self, name: str, params: str | dict = "paper"):
        """Build (and cache) one benchmark kernel at the requested size."""
        from repro import rvv
        bench = rvv.get_benchmark(name)
        kw = self._build_params(bench, params)
        key = (name, tuple(sorted(kw.items())))
        if key not in self._built:
            self._built[key] = bench.build(**kw)
        return self._built[key]

    def prepared(self, name: str, fold: bool | None = None,
                 max_events: int | None = None,
                 machine=DEFAULT_MACHINE,
                 params: str | dict = "paper") -> simulator.PreparedTrace:
        """Expanded (+folded / truncated) trace per benchmark, cached.

        The fold warm-up is a function of the static L1 geometry only
        (``machine.l1_sets`` / ``l1_ways``), so it is part of the cache key;
        the traced latency values never are.
        """
        from repro import rvv
        if fold is None:
            fold = self.fold
        if max_events is not None:
            fold = False                  # truncation is the legacy mode
        warm = folding.warm_lines_for(machine.l1_sets, machine.l1_ways)
        kw = self._build_params(rvv.get_benchmark(name), params)
        # Unfolded preparations never read the warm-up, so they are shared
        # across L1 geometries instead of duplicated per geometry.
        key = (name, tuple(sorted(kw.items())), fold, max_events,
               warm if fold else None)
        if key not in self._prepared:
            self._prepared[key] = simulator.prepare(
                self.built(name, params).program, fold=fold,
                max_events=max_events, warm_lines=warm)
        return self._prepared[key]

    def reset(self) -> None:
        """Drop every cache and counter (the jit cache is XLA's, not ours)."""
        self._built.clear()
        self._prepared.clear()
        self.history.clear()
        self._compiles = 0
        self._dispatches = 0

    # -- accounting -------------------------------------------------------

    def compile_count(self) -> int:
        """Engine compiles this session triggered (one per new (shape
        bucket, L1 geometry) signature)."""
        return self._compiles

    def dispatch_count(self) -> int:
        """Engine dispatches this session issued."""
        return self._dispatches

    def _simulate(self, preps, config, machine):
        c0, d0 = simulator.compile_count(), simulator.dispatch_count()
        out = simulator.simulate_grid(preps, config, machine,
                                      batch_programs=self.batch_programs)
        self._compiles += simulator.compile_count() - c0
        self._dispatches += simulator.dispatch_count() - d0
        return out

    def _simulate_cluster(self, preps, config, machine, cluster):
        """Cluster-engine grid call with the same compile/dispatch
        accounting as :meth:`_simulate` (the cluster engine increments the
        simulator-module counters, so one probe covers both engines)."""
        from repro.cluster import simulate_cluster_grid
        c0, d0 = simulator.compile_count(), simulator.dispatch_count()
        out = simulate_cluster_grid(preps, config, machine, cluster,
                                    batch_programs=self.batch_programs)
        self._compiles += simulator.compile_count() - c0
        self._dispatches += simulator.dispatch_count() - d0
        return out

    def _refine(self, names, out, config, machine, params) -> None:
        """Re-simulate, in place, every program whose fold certificate
        failed at any grid point and whose full trace is affordable."""
        if "fold_exact" not in out:
            return
        for pi, name in enumerate(names):
            if out["fold_exact"][pi].all():
                continue
            rows = self.built(name, params).program.num_instructions
            if rows > self.refine_max_rows:
                continue
            sub = self._simulate(
                [self.prepared(name, fold=False, machine=machine,
                               params=params)], config, machine)
            for k in out:
                out[k][pi] = sub[k][0] if k != "fold_exact" else True

    def _refine_cluster(self, names, out, config, machine, sweep) -> None:
        """Cluster analogue of :meth:`_refine`: re-simulate, unfolded and
        per failing ``cores`` point, every program whose cluster fold
        certificate failed (the shared L2 can break a period alignment
        that holds single-core, so certificates are per (kernel, cores))."""
        if "fold_exact" not in out:
            return
        for pi, name in enumerate(names):
            if out["fold_exact"][pi].all():
                continue
            rows = self.built(
                name, sweep.kernel_params).program.num_instructions
            if rows > self.refine_max_rows:
                continue
            prep = self.prepared(name, fold=False, machine=machine,
                                 params=sweep.kernel_params)
            for ki, n in enumerate(sweep.cores):
                if out["fold_exact"][pi, ki].all():
                    continue
                sub = self._simulate_cluster(
                    [prep], config, machine, sweep.cluster_config(n))
                for k in out:
                    out[k][pi, ki] = sub[k][0] if k != "fold_exact" \
                        else True

    # -- execution --------------------------------------------------------

    def grid(self, names, config: SweepConfig, machine=DEFAULT_MACHINE,
             fold: bool | None = None, max_events: int | None = None,
             refine: bool | None = None,
             params: str | dict = "paper") -> dict[str, np.ndarray]:
        """The legacy-shaped sweep call: P named kernels x a flat (C,)
        config axis (x M machine points when ``machine`` is a
        :class:`MachineSweep`), returning raw counter arrays.  Prefer
        :meth:`run` with a declarative :class:`Sweep`; this is the engine
        room it and the ``benchmarks.common`` shim share.
        """
        if fold is None:
            fold = self.fold
        if refine is None:
            refine = self.refine
        names = list(names)
        preps = [self.prepared(n, fold=fold, max_events=max_events,
                               machine=machine, params=params)
                 for n in names]
        out = self._simulate(preps, config, machine)
        if fold and refine:
            self._refine(names, out, config, machine, params)
        return out

    def run(self, sweep: Sweep) -> SweepResult:
        """Execute a declarative sweep.

        Planning: for each L1 geometry (static — its own engine build) the
        kernels are grouped by padded shape bucket and each (bucket,
        geometry) group is issued as one engine call — a single fused
        dispatch when ``batch_programs`` is on, per-program dispatches
        sharing the group's one compiled executable otherwise.  The traced
        latency grid rides inside every dispatch; uncertified folds are
        refined per geometry exactly as :meth:`grid` does.

        Cluster sweeps (:attr:`Sweep.is_cluster`) add the static ``cores``
        axis to the plan loop: one cluster-engine call per (bucket,
        geometry, cores) group — each a plan entry carrying ``cores`` —
        and the result grid gains the cluster counters with ``cycles`` as
        the cluster makespan.
        """
        fold = self.fold if sweep.fold is None else sweep.fold
        if sweep.max_events is not None:
            fold = False
        names = list(sweep.kernels)
        config = sweep.config()
        c0, d0 = self._compiles, self._dispatches
        cluster_mode = sweep.is_cluster
        plan = []
        per_geo = []
        for geo in sweep.l1_geometry:
            machines = sweep.machine_sweep(geo)
            preps = {n: self.prepared(n, fold=fold,
                                      max_events=sweep.max_events,
                                      machine=machines,
                                      params=sweep.kernel_params)
                     for n in names}
            groups: dict[int, list[str]] = {}
            for n in names:
                bucket = simulator._bucket(preps[n].num_rows)
                groups.setdefault(bucket, []).append(n)
            parts: dict[str, dict[str, np.ndarray]] = {}
            for bucket in sorted(groups):
                group = groups[bucket]
                group_preps = [preps[n] for n in group]
                if cluster_mode:
                    subs = []
                    for ncores in sweep.cores:
                        subs.append(self._simulate_cluster(
                            group_preps, config, machines,
                            sweep.cluster_config(ncores)))
                        plan.append(dict(
                            l1_geometry=str(geo), bucket=bucket,
                            cores=ncores, kernels=list(group),
                            fused=bool(self.batch_programs)))
                    for gi, n in enumerate(group):
                        parts[n] = {k: np.stack([s[k][gi] for s in subs])
                                    for k in subs[0]}        # (K, C, M)
                else:
                    sub = self._simulate(group_preps, config, machines)
                    plan.append(dict(l1_geometry=str(geo), bucket=bucket,
                                     kernels=list(group),
                                     fused=bool(self.batch_programs)))
                    for gi, n in enumerate(group):
                        parts[n] = {k: v[gi] for k, v in sub.items()}
            shape_cm = parts[names[0]]["cycles"].shape  # (C, M) / (K, C, M)
            for n in names:                  # normalise across buckets
                parts[n].setdefault(
                    "fold_exact", np.ones(shape_cm, bool))
            geo_out = {k: np.stack([parts[n][k] for n in names])
                       for k in parts[names[0]]}
            if fold and self.refine:
                if cluster_mode:
                    self._refine_cluster(names, geo_out, config, machines,
                                         sweep)
                else:
                    self._refine(names, geo_out, config, machines,
                                 sweep.kernel_params)
            per_geo.append(geo_out)
        axes = sweep.axes()
        if sweep.config_points is not None:
            cshape = (len(sweep.config_points),)
        else:
            cshape = (len(sweep.capacity), len(sweep.policy),
                      len(sweep.alloc_no_fetch))
        mshape = (len(sweep.mem_latency), len(sweep.l1_hit_cycles),
                  len(sweep.uop_hit_cycles))
        data = {}
        for k in per_geo[0]:
            if cluster_mode:
                # (G, P, K, C, M) -> geometry and cores move to their
                # canonical slots after the config axes.
                stacked = np.stack([g[k] for g in per_geo])
                g, p, kc = stacked.shape[:3]
                stacked = stacked.reshape((g, p, kc) + cshape + mshape)
                data[k] = np.moveaxis(
                    stacked, (0, 2),
                    (1 + len(cshape), 2 + len(cshape)))
            else:
                stacked = np.stack([g[k] for g in per_geo])  # (G, P, C, M)
                g, p = stacked.shape[:2]
                stacked = stacked.reshape((g, p) + cshape + mshape)
                # geometry moves to its canonical slot: after the config
                # axes.
                data[k] = np.moveaxis(stacked, 0, 1 + len(cshape))
        meta = dict(
            plan=plan,
            compiles=self._compiles - c0,
            dispatches=self._dispatches - d0,
            points=int(np.prod([len(a) for a in axes])),
            axes={a.name: [str(v) if a.name in ("l1_geometry", "config")
                           else v for v in a.values] for a in axes},
            kernel_params=(sweep.kernel_params
                           if isinstance(sweep.kernel_params, str)
                           else dict(sweep.kernel_params)),
            fold=fold,
        )
        if cluster_mode:
            cl0 = sweep.cluster_config(1)
            meta["cluster"] = dict(
                cores=list(sweep.cores), l2_sets=cl0.l2_sets,
                l2_ways=cl0.l2_ways, mem_channels=cl0.mem_channels,
                l2_hit_cycles=cl0.l2_hit_cycles, l2_bytes=cl0.l2_bytes)
        lowered = getattr(sweep, "_lowered", ())
        if lowered:
            meta["networks"] = [net.summary() for net in lowered]
        self.history.append(meta)
        return SweepResult(axes, data, meta)


# ---------------------------------------------------------------------------
# Process-default session + the raw-program front door.
# ---------------------------------------------------------------------------


_DEFAULT_SESSION: Session | None = None


def default_session() -> Session:
    """The process-default Session the benchmark layer shares."""
    global _DEFAULT_SESSION
    if _DEFAULT_SESSION is None:
        _DEFAULT_SESSION = Session()
    return _DEFAULT_SESSION


def reset_default_session() -> Session:
    """Replace the process-default Session with a fresh one (tests use the
    ``fresh_default_session`` pytest fixture, which restores the old one)."""
    global _DEFAULT_SESSION
    _DEFAULT_SESSION = Session()
    return _DEFAULT_SESSION


def sweep_program(program_or_events, config: SweepConfig,
                  machine=DEFAULT_MACHINE, fold: bool = False,
                  max_events: int | None = None) -> dict[str, np.ndarray]:
    """Sweep one raw Program / EventStream / PreparedTrace over a flat
    config axis — the front door for traces that are not registered
    kernels (the deprecated ``simulator.simulate_sweep`` delegates here).
    Returns (C,)-shaped counter arrays, (C, M)-shaped under a
    :class:`MachineSweep`."""
    prep = simulator.prepare(program_or_events, fold=fold,
                             max_events=max_events, machine=machine)
    out = simulator.simulate_grid([prep], config, machine)
    return {k: v[0] for k, v in out.items()}
