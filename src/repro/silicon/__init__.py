"""Calibrated silicon backend: pluggable SRAM macro models + the metrics
that let any sweep re-price its area/energy axes per backend.

``repro.silicon`` is the layer between the cost model and the sweep front
door the ROADMAP's "calibrated silicon backend" item asked for: a
:class:`MacroModel` protocol (area / access energy / leakage as functions
of a words x bits x banks geometry), a registry with three backends
(``flop`` — the legacy flop-derived constants, bit-identical default;
``sram6t`` — an OpenRAM-style analytic 6T curve with edge-scaled
periphery; ``table`` — interpolated from published datapoints, exact at
its anchors), and macro-parameterised metrics (``silicon_area``,
``silicon_cluster_area``, ``silicon_energy``, ``silicon_edp``) registered
through :func:`repro.metrics.register` with no core-engine edits.  See
``docs/silicon.md`` and ``benchmarks/dse.py`` (the 3-objective DSE driver
built on top).
"""

from repro.silicon.models import (AU_PER_UM2, BITCELL_UM2,
                                  DEFAULT_MACRO_MODEL, FlopMacroModel,
                                  MacroModel, Sram6TMacroModel,
                                  TableMacroModel, get_macro_model,
                                  macro_catalog, macro_model_names,
                                  register_macro_model)
from repro.silicon import metrics as _macro_metrics  # noqa: F401  (registers)

__all__ = [
    "AU_PER_UM2", "BITCELL_UM2", "DEFAULT_MACRO_MODEL", "FlopMacroModel",
    "MacroModel", "Sram6TMacroModel", "TableMacroModel", "get_macro_model",
    "macro_catalog", "macro_model_names", "register_macro_model",
]
