"""Pluggable SRAM macro models: per-geometry area / access-energy /
leakage curves behind one protocol.

Every iso-area comparison in this repo (the Pareto frontier, the cluster
iso-SRAM-budget sweeps, the DSE driver) prices cache capacity through ONE
constant, ``costmodel.SRAM_AU_PER_BIT`` — an assumption anchored on a 28 nm
6T bitcell, not a calibration (the long-standing ``TODO(cal)``).  This
module closes that item the way OpenRAM-style design-space flows do: a
macro *model* maps a (words x bits x banks) geometry to area, per-access
energy and leakage, and a registry makes the model a swappable parameter of
the metric layer (``derive("area_with_l1", macro_model="sram6t")``) instead
of a hard-coded constant.

Three backends ship:

  * ``flop`` — the legacy flop-derived constants, **bit-identical** to the
    closed forms the repo has always used (``bits * SRAM_AU_PER_BIT +
    SRAM_PERIPHERY_AU``, flat 12.0-unit access energy, ``leak_per_au``
    leakage).  This is the default everywhere, so every existing benchmark
    number is unchanged; the class docstring is the constant's derivation.
  * ``sram6t`` — an OpenRAM-style analytic 6T curve: raw bitcell array
    plus periphery that scales with the folded array's *edge* (wordline
    drivers + row decoder ~ rows, sense amps + column muxes ~ cols) plus a
    fixed control block.  Small macros stop looking unrealistically cheap:
    a 1 KB macro is ~32% array, a 4 KB macro ~50%, a 64 KB macro ~77% —
    the classic macro-efficiency curve.
  * ``table`` — piecewise interpolation (linear in log2 bits) through
    user-supplied published datapoints, exact at its anchors.  The
    registered default carries 28 nm-compiler-shaped anchors; replace it
    with ``register_macro_model(TableMacroModel("table", pts),
    override=True)`` when a measured datasheet lands.

Units: everything is in the repo's calibrated *area units* (au) and
model energy/power units, bridged to silicon via the documented anchor
(one flop bit = ``REG_AU_PER_BIT`` au ~ 4x a 0.127 um^2 28 nm 6T bitcell,
so ``AU_PER_UM2 = REG_AU_PER_BIT / (4 * 0.127)``).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import costmodel

__all__ = [
    "MacroModel", "FlopMacroModel", "Sram6TMacroModel", "TableMacroModel",
    "register_macro_model", "get_macro_model", "macro_model_names",
    "macro_catalog", "DEFAULT_MACRO_MODEL", "AU_PER_UM2", "BITCELL_UM2",
]

# -- the au <-> um^2 calibration bridge (see costmodel.SRAM_AU_PER_BIT) ----
BITCELL_UM2 = 0.127                 # published 28 nm planar 6T bitcell
# One flop bit (storage + mux/clock load) ~ 4x a 6T bitcell in drawn area;
# the flop bit is REG_AU_PER_BIT au by calibration, which fixes the scale.
AU_PER_UM2 = costmodel.REG_AU_PER_BIT / (4.0 * BITCELL_UM2)


@runtime_checkable
class MacroModel(Protocol):
    """One silicon backend: geometry -> area / access energy / leakage.

    ``words`` is the number of addressable entries (cache lines for an L1
    macro), ``bits`` the width of one entry, ``banks`` how many equal
    sub-arrays the macro is split into (each bank gets its own periphery;
    an access activates one bank).  All three broadcast as numpy arrays,
    and every method is vectorized — the metric layer evaluates whole
    sweep grids in one call.
    """

    name: str

    def area(self, words, bits, banks=1) -> np.ndarray:
        """Total macro area (au), periphery included."""
        ...

    def access_energy(self, words, bits, banks=1) -> np.ndarray:
        """Dynamic energy of one access (model energy units)."""
        ...

    def leakage(self, words, bits, banks=1) -> np.ndarray:
        """Static leakage power (model power units)."""
        ...


def _geometry(words, bits, banks):
    words = np.asarray(words, np.int64)
    bits = np.asarray(bits, np.int64)
    banks = np.asarray(banks, np.int64)
    if (np.asarray(banks) < 1).any():
        raise ValueError(f"banks must be >= 1, got {banks}")
    return np.broadcast_arrays(words, bits, banks)


@dataclasses.dataclass(frozen=True)
class FlopMacroModel:
    """The legacy flop-derived constants as a macro model (the default).

    Derivation of the pinned constant (carried over from
    ``costmodel.SRAM_AU_PER_BIT``, whose ``TODO(cal)`` this class closes):
    the paper gives only area *ratios*, so the calibrated
    ``REG_AU_PER_BIT`` fixes the au scale; a flop + mux/clock load in
    28 nm is ~4x a 6T bitcell in drawn area, hence ``SRAM_AU_PER_BIT =
    REG_AU_PER_BIT / 4`` with a single flat ``SRAM_PERIPHERY_AU`` adder
    per macro.  Access energy is the flat ``PowerParams.e_l1_access``
    (12.0 units for any geometry) and leakage is ``area * leak_per_au`` —
    exactly what the power model has always charged.  Bit-identity of
    ``area`` with the legacy ``costmodel.l1_sram_area`` closed form is a
    regression pin (``tests/test_silicon.py``).
    """

    name: str = "flop"

    def area(self, words, bits, banks=1) -> np.ndarray:
        words, bits, banks = _geometry(words, bits, banks)
        total_bits = words * bits * banks
        return (total_bits * costmodel.SRAM_AU_PER_BIT
                + costmodel.SRAM_PERIPHERY_AU * banks)

    def access_energy(self, words, bits, banks=1) -> np.ndarray:
        words, bits, banks = _geometry(words, bits, banks)
        return np.broadcast_to(
            np.asarray(costmodel.DEFAULT_POWER.e_l1_access), words.shape)

    def leakage(self, words, bits, banks=1) -> np.ndarray:
        return self.area(words, bits, banks) \
            * costmodel.DEFAULT_POWER.leak_per_au


@dataclasses.dataclass(frozen=True)
class Sram6TMacroModel:
    """OpenRAM-style analytic 6T macro curve.

    Per bank, the array is folded to a near-square aspect (rows ~ cols ~
    sqrt(bits)), so the periphery — wordline drivers + row decoder along
    one edge, sense amps + column muxes + write drivers along the other —
    scales with the array *edge* while the cells scale with its *area*:

        area_bank = bits * cell_au  +  edge_au * sqrt(bits)  +  fixed_au

    Anchors (documented, not fitted): the cell term reuses the repo's
    28 nm 6T bitcell bridge (``SRAM_AU_PER_BIT``); ``edge_au``/``fixed_au``
    put a 4 KB macro at ~50% array efficiency — the OpenRAM ballpark for
    small compiler macros — which lands 1 KB at ~32% and 64 KB at ~77%.
    Relative to the ``flop`` backend (whose periphery is a flat 9000 au),
    small macros get *more* expensive and the gap narrows with size: that
    is exactly the reordering the DSE acceptance criterion exercises.

    Access energy activates one bank: a fixed decode term plus wordline +
    bitline capacitance proportional to the bank edge, calibrated to meet
    the legacy flat 12.0 units at the 16 KB reference macro.  Leakage is
    per-cell (6T cells leak ~half the model's per-au logic rate) plus a
    periphery share.
    """

    name: str = "sram6t"
    cell_au: float = costmodel.SRAM_AU_PER_BIT      # raw 6T array density
    edge_au: float = 1400.0      # wordline/decoder + sense/mux per edge unit
    fixed_au: float = 12000.0    # control FSM, timing, redundancy per bank
    e_decode: float = 2.0        # fixed decode+control energy per access
    e_edge: float = 10.0 / 362.0  # edge energy; 12.0 total at 16 KB (1 bank)
    leak_scale: float = 0.5      # 6T cell leakage vs logic, per au

    def _bank_bits(self, words, bits, banks):
        words, bits, banks = _geometry(words, bits, banks)
        return (words * bits / banks).astype(np.float64), banks

    def area(self, words, bits, banks=1) -> np.ndarray:
        bank_bits, banks = self._bank_bits(words, bits, banks)
        bank = (bank_bits * self.cell_au
                + self.edge_au * np.sqrt(bank_bits) + self.fixed_au)
        return banks * bank

    def access_energy(self, words, bits, banks=1) -> np.ndarray:
        bank_bits, _ = self._bank_bits(words, bits, banks)
        return self.e_decode + self.e_edge * np.sqrt(bank_bits)

    def leakage(self, words, bits, banks=1) -> np.ndarray:
        return self.area(words, bits, banks) \
            * costmodel.DEFAULT_POWER.leak_per_au * self.leak_scale


@dataclasses.dataclass(frozen=True)
class TableMacroModel:
    """Interpolated macro model from published datapoints.

    ``points`` is a tuple of ``(total_bits, area_au, access_energy,
    leakage)`` anchors, at least two, sorted by capacity.  Between anchors
    each quantity is linear in ``log2(total_bits)`` (macro curves are
    close to straight on a log-capacity axis); outside the anchor range
    the edge values clamp (``np.interp`` semantics — extrapolating a
    published table would be invention).  At an anchor capacity the model
    returns the published value **exactly** (pinned in
    ``tests/test_silicon.py``); banks split the capacity into equal
    sub-macros, each read off the table at its own size.
    """

    name: str
    points: tuple = ()

    def __post_init__(self):
        pts = tuple(tuple(float(x) for x in p) for p in self.points)
        if len(pts) < 2:
            raise ValueError(
                f"TableMacroModel needs >= 2 anchor points, got {len(pts)}")
        if any(len(p) != 4 for p in pts):
            raise ValueError(
                "each anchor is (total_bits, area_au, access_energy, "
                "leakage)")
        if list(p[0] for p in pts) != sorted(set(p[0] for p in pts)):
            raise ValueError("anchor capacities must be strictly increasing")
        object.__setattr__(self, "points", pts)

    def _interp(self, words, bits, banks, column):
        words, bits, banks = _geometry(words, bits, banks)
        bank_bits = (words * bits / banks).astype(np.float64)
        xp = np.log2([p[0] for p in self.points])
        fp = np.asarray([p[column] for p in self.points])
        return np.interp(np.log2(bank_bits), xp, fp)

    def area(self, words, bits, banks=1) -> np.ndarray:
        _, _, banks = _geometry(words, bits, banks)
        return banks * self._interp(words, bits, banks, 1)

    def access_energy(self, words, bits, banks=1) -> np.ndarray:
        return self._interp(words, bits, banks, 2)

    def leakage(self, words, bits, banks=1) -> np.ndarray:
        _, _, banks = _geometry(words, bits, banks)
        return banks * self._interp(words, bits, banks, 3)


def _kb(n):
    return n * 1024 * 8


# Default ``table`` anchors: 28 nm-compiler-shaped datapoints — raw array
# from the 0.127 um^2 bitcell times the macro-efficiency ladder published
# for small/medium compiler macros (~2.0x array at 4 KB, ~1.5x at 32 KB,
# ~1.35x at 256 KB), converted um^2 -> au through AU_PER_UM2; energies
# bracket the legacy flat 12.0 units at 16 KB.  These are engineering
# anchors, not a measured datasheet: swap the instance (override=True)
# when one lands in PAPERS.md.
_TABLE_ANCHORS = tuple(
    (bits, AU_PER_UM2 * BITCELL_UM2 * bits * factor, energy,
     AU_PER_UM2 * BITCELL_UM2 * bits * factor
     * costmodel.DEFAULT_POWER.leak_per_au * 0.5)
    for bits, factor, energy in (
        (_kb(1), 2.9, 5.0),
        (_kb(4), 2.0, 8.0),
        (_kb(16), 1.65, 12.0),
        (_kb(32), 1.5, 14.5),
        (_kb(256), 1.35, 24.0),
    ))


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

DEFAULT_MACRO_MODEL = "flop"

_MACRO_REGISTRY: dict[str, MacroModel] = {}


def register_macro_model(model: MacroModel,
                         override: bool = False) -> MacroModel:
    """Register a macro model under ``model.name``; re-registering an
    existing name raises unless ``override=True``.  Returns the model so
    the call composes with construction."""
    if not isinstance(model, MacroModel):
        raise TypeError(
            f"macro model must implement the MacroModel protocol "
            f"(area/access_energy/leakage + name), got {model!r}")
    if model.name in _MACRO_REGISTRY and not override:
        raise ValueError(
            f"macro model {model.name!r} registered twice "
            "(pass override=True to replace)")
    _MACRO_REGISTRY[model.name] = model
    return model


def get_macro_model(model=None) -> MacroModel:
    """Resolve a macro model: ``None`` -> the ``flop`` default, a name ->
    registry lookup (unknown names raise with the sorted menu), an object
    implementing the protocol -> passed through."""
    if model is None:
        model = DEFAULT_MACRO_MODEL
    if isinstance(model, str):
        try:
            return _MACRO_REGISTRY[model]
        except KeyError:
            raise KeyError(
                f"unknown macro model {model!r}; registered: "
                f"{', '.join(sorted(_MACRO_REGISTRY))}") from None
    if isinstance(model, MacroModel):
        return model
    raise TypeError(
        f"macro_model must be a name or a MacroModel, got {model!r}")


def macro_model_names() -> list[str]:
    """Sorted names of every registered macro model."""
    return sorted(_MACRO_REGISTRY)


def macro_catalog(words: int = 512, bits: int = 256) -> dict[str, dict]:
    """JSON-safe registry dump evaluated at one reference geometry
    (default: a 2-way 16 KB L1's 512 lines x 256 b — the ``sram6t``
    energy-calibration point) — what ``run.py --json`` records so a
    report names the silicon its areas assume."""
    return {name: dict(
        area_au=float(m.area(words, bits)),
        access_energy=float(m.access_energy(words, bits)),
        leakage=float(m.leakage(words, bits)),
        kind=type(m).__name__,
    ) for name, m in sorted(_MACRO_REGISTRY.items())}


register_macro_model(FlopMacroModel())
register_macro_model(Sram6TMacroModel())
register_macro_model(TableMacroModel("table", _TABLE_ANCHORS))
