"""Macro-calibrated metrics, registered through :func:`repro.metrics.
register` — the no-core-edit extension point the metrics PR promised.

Each metric takes a ``macro_model`` parameter (a registry name or a
:class:`repro.silicon.MacroModel` instance; default ``"flop"``, the
bit-identical legacy constants), so one ``derive`` call re-prices a whole
sweep grid under a different silicon assumption:

    r = res.derive("silicon_area", macro_model="sram6t", out="area_6t")

``silicon_area`` / ``silicon_cluster_area`` are the macro-parameterised
twins of ``area_with_l1`` / ``cluster_area`` — under ``macro_model="flop"``
they are **bit-identical** to the legacy metrics (pinned in
``tests/test_silicon.py``), so every existing benchmark number is
unchanged by this layer existing.  ``silicon_energy`` re-prices the power
model's flat per-access L1 energy with the macro's per-geometry access
energy and adds the macro's leakage (which the core power model, whose
area explicitly excludes L1 macros, has never charged).
"""

from __future__ import annotations

import numpy as np

from repro import metrics as _metrics
from repro.core import costmodel
from repro.silicon.models import get_macro_model

L1_LINE_BITS = 32 * 8     # L1Geometry.LINE_BYTES * 8


def _l1_macro(ctx):
    """(model, words, bits) of the sweep's per-core L1 macro: one word per
    cache line (sets x ways) of 256 bits."""
    model = get_macro_model(ctx.params.get("macro_model"))
    words = ctx.axis_grid("l1_sets") * ctx.axis_grid("l1_ways")
    return model, words, L1_LINE_BITS


@_metrics.register("l1_macro_area", "model",
                   "per-core L1 SRAM macro area (au) under the macro_model "
                   "backend (default 'flop', the bit-identical legacy "
                   "constants) at the sweep's l1_geometry",
                   params=("macro_model",))
def _l1_macro_area(ctx):
    model, words, bits = _l1_macro(ctx)
    return model.area(words, bits)


@_metrics.register("l1_macro_access_energy", "model",
                   "dynamic energy of one L1 macro access under the "
                   "macro_model backend ('flop' reads the legacy flat "
                   "PowerParams.e_l1_access)",
                   params=("macro_model",))
def _l1_macro_access_energy(ctx):
    model, words, bits = _l1_macro(ctx)
    return model.access_energy(words, bits)


@_metrics.register("silicon_area", "model",
                   "total_area plus the macro_model-priced L1 macro — the "
                   "macro-parameterised twin of area_with_l1 "
                   "(bit-identical to it under macro_model='flop')",
                   params=("macro_model", "dispersed", "n_lanes"))
def _silicon_area(ctx):
    return ctx.counter("total_area") + ctx.counter("l1_macro_area")


@_metrics.register("silicon_cluster_area", "model",
                   "cores * silicon_area plus the macro_model-priced "
                   "shared-L2 macro from meta['cluster'] — the twin of "
                   "cluster_area (bit-identical under macro_model='flop')",
                   params=("macro_model", "dispersed", "n_lanes"))
def _silicon_cluster_area(ctx):
    cl = _metrics._cluster_meta(ctx)
    model = get_macro_model(ctx.params.get("macro_model"))
    l2_au = float(model.area(cl["l2_sets"] * cl["l2_ways"],
                             L1_LINE_BITS)) if cl["l2_bytes"] else 0.0
    return ctx.axis_grid("cores") * ctx.counter("silicon_area") + l2_au


@_metrics.register("sram_access_energy", "model",
                   "total L1 macro dynamic energy over the run: the power "
                   "model's L1 access count (l1_hits + mem_reads + "
                   "mem_writes) times the macro's per-access energy",
                   params=("macro_model",))
def _sram_access_energy(ctx):
    l1_ev = (ctx.counter("l1_hits") + ctx.counter("mem_reads")
             + ctx.counter("mem_writes")).astype(np.float64)
    return l1_ev * ctx.counter("l1_macro_access_energy")


def _cores_grid(ctx):
    if any(a.name == "cores" for a in ctx.result.axes):
        return ctx.axis_grid("cores")
    return np.asarray(1)


@_metrics.register("silicon_energy", "model",
                   "application energy with the flat L1 access energy "
                   "re-priced by the macro_model backend, plus the L1 "
                   "macro's leakage (cores * leak * scaled_cycles) the "
                   "core power model never charges; equals energy + L1 "
                   "leakage under macro_model='flop'",
                   params=("macro_model", "dispersed", "n_lanes", "pp"))
def _silicon_energy(ctx):
    pp = ctx.params.get("pp", costmodel.DEFAULT_POWER)
    model, words, bits = _l1_macro(ctx)
    l1_ev = (ctx.counter("l1_hits") + ctx.counter("mem_reads")
             + ctx.counter("mem_writes")).astype(np.float64)
    reprice = l1_ev * (model.access_energy(words, bits) - pp.e_l1_access)
    leak = _cores_grid(ctx) * model.leakage(words, bits) \
        * ctx.counter("scaled_cycles")
    return ctx.counter("energy") + reprice + leak


@_metrics.register("silicon_edp", "model",
                   "macro-calibrated energy-delay product: silicon_energy "
                   "* scaled_cycles",
                   params=("macro_model", "dispersed", "n_lanes", "pp"))
def _silicon_edp(ctx):
    return ctx.counter("silicon_energy") * ctx.counter("scaled_cycles")
