"""Analytic model FLOPs per (arch x shape) — the roofline's MODEL_FLOPS.

MODEL_FLOPS = 6 * N * D for dense training (2ND forward + 4ND backward),
6 * N_active * D for MoE, plus the attention quadratic term
(12 * B * H * S^2 * hd per layer trained; 4 * B * H * S * hd per decoded
token).  Inference (prefill/decode) uses the 2x forward-only factors.
The ratio MODEL_FLOPS / HLO_FLOPS flags remat/redundancy waste.
"""

from __future__ import annotations

from repro.configs import ShapeConfig
from repro.configs.base import ArchConfig


def _attn_layers(cfg: ArchConfig) -> int:
    if cfg.ssm:
        return 0
    if cfg.hybrid:
        return sum(1 for i in range(cfg.num_layers) if i % 3 == 2)
    n = cfg.num_layers
    if cfg.encoder_decoder:
        n += cfg.num_encoder_layers + cfg.num_layers   # self + cross
    return n


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Returns dict(model_flops, matmul_param_flops, attn_flops, tokens)."""
    b = shape.global_batch
    train = shape.kind == "train"
    n_active = cfg.active_param_count()

    if shape.kind == "decode":
        tokens = b                       # one new token per sequence
        ctx = shape.seq_len
        fwd_factor = 2.0
        # attention reads the whole KV context per token
        hd = cfg.head_dim
        la = _attn_layers(cfg)
        if cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        attn = 4.0 * b * cfg.num_heads * ctx * hd * la
    else:
        tokens = b * shape.seq_len
        fwd_factor = 6.0 if train else 2.0
        hd = cfg.head_dim
        la = _attn_layers(cfg)
        s = shape.seq_len
        causal_frac = 0.5 if not cfg.encoder_decoder else 1.0
        if cfg.sliding_window:
            s_eff = min(s, cfg.sliding_window)
            quad = b * cfg.num_heads * s * s_eff * hd
        else:
            quad = b * cfg.num_heads * s * s * hd * causal_frac
        attn = (2.0 if not train else 6.0) * 2.0 * quad * la

    param_flops = fwd_factor * n_active * tokens
    return dict(model_flops=param_flops + attn,
                matmul_param_flops=param_flops, attn_flops=attn,
                tokens=tokens)


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeConfig,
                       chips: int = 256, microbatches: int = 1) -> float:
    """Minimum-HBM-traffic estimate per chip per step (documented formulas;
    the op-level loop-corrected HLO bytes are an upper bound because they
    count every intermediate at every op — VMEM/register-resident values
    included).  Components:

    train:  3x param reads (fwd + remat recompute + bwd) x microbatches
            + grad write/read (f32) + AdamW state R/W (3 x f32 R + 2 x W)
            + 2x layer-boundary activation R/W
            + logits write/read (f32)
    decode: 1x param read + KV/state cache read + write of one token slot
    prefill: 1x param read + 2x activation R/W + KV write
    """
    p_total = cfg.param_count() * 2.0            # bf16
    p_dev = p_total / chips
    d = cfg.d_model
    b = shape.global_batch
    if shape.kind == "decode":
        ctx = shape.seq_len
        if cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        if cfg.ssm:
            cache = (cfg.num_layers * b * cfg.ssm_expand * d
                     * (cfg.ssm_state * 4 + 2.0))
        elif cfg.mla:
            cache = (cfg.num_layers * b * ctx
                     * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0)
        else:
            la = _attn_layers(cfg)
            cache = (2 * la * b * ctx * cfg.num_kv_heads
                     * cfg.head_dim * 2.0)
        return (p_total + cache) / chips
    tokens = b * shape.seq_len
    act = tokens * d * 2.0 * cfg.num_layers      # boundary activations
    logits = tokens * cfg.vocab_size * 4.0
    if shape.kind == "prefill":
        return (p_total + 2 * act + logits) / chips
    n_params = cfg.param_count()
    opt = n_params * (3 * 4.0 + 2 * 4.0)         # m,v,master R + m,v W
    grads = n_params * 2 * 4.0
    return (3 * p_total * microbatches + grads + opt + 4 * act
            + 2 * logits) / chips


# v5e hardware constants (assignment).
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(cell: dict, cfg: ArchConfig, shape: ShapeConfig,
                   chips: int = 256) -> dict:
    """Three roofline terms (seconds) from one dry-run cell record.

    The parsed HLO is the per-device program, so parsed FLOPs/bytes are
    already per-chip.
    """
    flops_dev = cell.get("dot_flops_loop_corrected") or 0.0
    bytes_dev_ub = cell.get("bytes_loop_corrected") or 0.0
    coll_dev = (cell.get("collectives") or {}).get("collective_bytes", 0.0)
    mf = model_flops(cfg, shape)
    mb = cell.get("microbatches", 1)
    bytes_dev = analytic_hbm_bytes(cfg, shape, chips, mb)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_memory_ub = bytes_dev_ub / HBM_BW
    t_coll = coll_dev / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    useful = mf["model_flops"] / max(flops_dev * chips, 1.0)
    # step-time bracket: perfect compute/comm/memory overlap (max of terms)
    # vs fully serialized (sum); achievable MFU = model flops against the
    # perfectly-overlapped bound.
    t_lb = max(t_compute, t_memory, t_coll)
    t_ub = t_compute + t_memory + t_coll
    mfu_ub = mf["model_flops"] / (chips * PEAK_FLOPS * max(t_lb, 1e-12))
    return dict(t_compute=t_compute, t_memory=t_memory,
                t_memory_opbytes_ub=t_memory_ub, t_collective=t_coll,
                bottleneck=dom[1],
                model_flops=mf["model_flops"],
                hlo_flops_global=flops_dev * chips,
                useful_flop_ratio=useful,
                t_step_overlap=t_lb, t_step_serial=t_ub,
                mfu_upper_bound=mfu_ub,
                roofline_fraction=t_compute / max(t_lb, 1e-12))
