"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 100 --seq-len 128 --global-batch 8

Runs the fault-tolerant Trainer (checkpoint/restart, heartbeat, straggler
policy).  On a real cluster this entrypoint runs per host under
``jax.distributed.initialize`` with the mesh from ``launch.mesh``; in this
container it runs single-process (reduced configs recommended).
"""

from __future__ import annotations

import argparse

from repro.configs import get
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                     microbatches=args.microbatches, steps=args.steps,
                     checkpoint_every=max(args.steps // 4, 1),
                     checkpoint_dir=args.ckpt_dir)
    oc = OptConfig(peak_lr=args.lr, min_lr=args.lr / 10,
                   warmup_steps=max(args.steps // 20, 1),
                   total_steps=args.steps,
                   compress_grads=args.compress_grads)
    out = Trainer(cfg, tc, oc).run()
    h = out["history"]
    print(f"final loss {h[-1]['loss']:.4f} after {len(h)} steps "
          f"(restartable from {args.ckpt_dir})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
