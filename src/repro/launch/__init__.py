"""Launch layer: mesh, sharding rules, input specs, dry-run, train/serve."""
