"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in this
container: a 10-iteration scan of matmuls reports 1 matmul of FLOPs), so for
scan-over-layers models it undercounts by ~L x microbatches.  This module
re-walks the optimized HLO with loop multipliers:

  * splits the module into computations,
  * records per-computation collective result bytes (all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute) and dot FLOPs,
  * propagates multipliers through ``while`` ops using the
    ``known_trip_count`` backend config (scans have static trips) and
    through ``call``/``fusion``/``to_apply`` references,
  * returns totals that are correct for arbitrarily nested scans.

Dot FLOPs: 2 * prod(result_dims) * prod(contracting_dims); contracting dim
sizes are resolved from the lhs operand's recorded shape.  CPU-backend
oneDNN matmul custom-calls are handled with the same formula (k = lhs last
non-batch dim).
"""

from __future__ import annotations

import re

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(")
_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRIP = re.compile(r"known_trip_count.{0,12}?n.{0,6}?(\d+)")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_of(expr: str):
    m = _SHAPE.match(expr.strip())
    if not m:
        return None, None
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dt, dims


def _nbytes(dt, dims):
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _operand_names(s: str) -> list[str]:
    """Variable names from an operand list; newer XLA prints typed operands
    (``dot(f32[256,256]{1,0} %a, ...)``), older ones bare (``dot(%a, %b)``).
    """
    names = re.findall(r"%([\w\.\-_]+)", s)
    if names:
        return names
    return [tok.strip().split()[-1] for tok in s.split(",") if tok.strip()]


_SKIP_BYTES_OPS = ("get-tuple-element", "tuple(", "parameter(", "constant(",
                   "bitcast(", "after-all(", "partition-id(", "iota(")


class Computation:
    def __init__(self, name):
        self.name = name
        self.shapes: dict[str, tuple] = {}
        self.coll_bytes = {c: 0 for c in _COLLECTIVES}
        self.coll_count = 0
        self.dot_flops = 0.0
        self.bytes_accessed = 0.0
        self.children: list[tuple[str, int]] = []   # (comp name, multiplier)


def _parse_line(comp: Computation, line: str):
    m = _ASSIGN.match(line)
    if not m:
        return
    var, rhs = m.group(1), m.group(2)
    dt, dims = _shape_of(rhs)
    if dims is not None:
        comp.shapes[var] = (dt, dims)

    # Bytes accessed (result + resolvable operand shapes), skipping pure
    # bookkeeping ops; fusion internals are not double-counted because only
    # the fusion's boundary operands appear here.
    if dims is not None and not any(s in rhs for s in _SKIP_BYTES_OPS):
        b = _nbytes(dt, dims)
        om = _OPERANDS.search(rhs)
        if om:
            for name in _operand_names(om.group(1)):
                sh = comp.shapes.get(name)
                if sh and sh[1] is not None:
                    b += _nbytes(*sh)
        comp.bytes_accessed += b

    # Collectives ------------------------------------------------------
    for c in _COLLECTIVES:
        if re.search(rf"\b{c}(?:-start|-done)?\(", rhs):
            if dims is not None:
                comp.coll_bytes[c] += _nbytes(dt, dims)
                comp.coll_count += 1
            break

    # While loops ------------------------------------------------------
    if re.search(r"\bwhile\(", rhs):
        bm = re.search(r"body=%?([\w\.\-_]+)", rhs)
        tm = _TRIP.search(rhs)
        trip = int(tm.group(1)) if tm else 1
        if bm:
            comp.children.append((bm.group(1), trip))
        return

    # Calls / fusions ----------------------------------------------------
    for attr in ("calls=", "to_apply="):
        am = re.search(attr + r"%?([\w\.\-_]+)", rhs)
        if am:
            comp.children.append((am.group(1), 1))

    # Dot FLOPs ----------------------------------------------------------
    if re.search(r"\bdot\(", rhs) and dims is not None:
        ops = re.search(r"\bdot\(([^)]*)\)", rhs)
        lhs_k = _contracting_size(comp, rhs, ops)
        if lhs_k:
            comp.dot_flops += 2.0 * _nbytes("s8", dims) * lhs_k
    elif "__onednn$matmul" in rhs and dims is not None:
        ops = re.search(r"custom-call\(([^)]*)\)", rhs)
        if ops:
            names = _operand_names(ops.group(1))
            lhs = comp.shapes.get(names[0]) if names else None
            if lhs and lhs[1]:
                comp.dot_flops += 2.0 * _nbytes("s8", dims) * lhs[1][-1]


def _contracting_size(comp: Computation, rhs: str, ops) -> float:
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if not (cm and ops):
        return 0.0
    names = _operand_names(ops.group(1))
    lhs = comp.shapes.get(names[0]) if names else None
    if not lhs or lhs[1] is None:
        return 0.0
    k = 1.0
    for d in cm.group(1).split(","):
        if d and int(d) < len(lhs[1]):
            k *= lhs[1][int(d)]
    return k


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line.rstrip() == "}":
            cur = None
            continue
        sm = _COMP_START.match(line.strip())
        if sm and line.rstrip().endswith("{") and "->" in line:
            cur = Computation(sm.group(1))
            comps[cur.name] = cur
            # parameters also carry shapes
            for pm in re.finditer(r"%?([\w\.\-_]+):\s*([a-z0-9]+\[[\d,]*\])",
                                  line):
                dt, dims = _shape_of(pm.group(2))
                if dims is not None:
                    cur.shapes[pm.group(1)] = (dt, dims)
            continue
        if cur is not None:
            _parse_line(cur, line)
    return comps


def analyze(text: str, entry: str | None = None) -> dict:
    """Loop-corrected totals over the whole module."""
    comps = parse_module(text)
    if not comps:
        return {"error": "no computations parsed"}
    if entry is None:
        em = re.search(r"^ENTRY\s+%?([\w\.\-_]+)", text, re.M)
        entry = em.group(1) if em else next(iter(comps))

    totals = {c: 0.0 for c in _COLLECTIVES}
    totals["dot_flops"] = 0.0
    totals["bytes_accessed"] = 0.0
    totals["collective_count"] = 0.0
    seen_stack = []

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        seen_stack.append(name)
        for c in _COLLECTIVES:
            totals[c] += comp.coll_bytes[c] * mult
        totals["collective_count"] += comp.coll_count * mult
        totals["dot_flops"] += comp.dot_flops * mult
        totals["bytes_accessed"] += comp.bytes_accessed * mult
        for child, trip in comp.children:
            walk(child, mult * trip)
        seen_stack.pop()

    walk(entry, 1.0)
    totals["collective_bytes"] = sum(totals[c] for c in _COLLECTIVES)
    return totals


# ---------------------------------------------------------------------------
# Collective attribution: bytes by (op kind, source op_name), loop-corrected.
# ---------------------------------------------------------------------------

_COLL_LINE = re.compile(
    r"=\s+([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(.*?op_name=\"([^\"]*)\"")


def attribute_collectives(text: str, top: int = 25) -> list[tuple]:
    """(bytes, op, tag) per collective site, multiplied by loop trip counts.

    Tags collapse jit/while/remat frames so sites aggregate by model op.
    """
    comps = parse_module(text)
    em = re.search(r"^ENTRY\s+%?([\w\.\-_]+)", text, re.M)
    entry = em.group(1) if em else next(iter(comps))

    # per-computation multipliers
    mult: dict[str, float] = {}

    def walk(name, m):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, trip in comp.children:
            walk(child, m * trip)
    walk(entry, 1.0)

    # map line -> computation by re-scan
    agg: dict[tuple, float] = {}
    cur = None
    for line in text.splitlines():
        if line.rstrip() == "}":
            cur = None
            continue
        sm = _COMP_START.match(line.strip())
        if sm and line.rstrip().endswith("{") and "->" in line:
            cur = sm.group(1)
            continue
        m = _COLL_LINE.search(line)
        if m and cur is not None:
            dt, dims, op, name = m.groups()
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            tag = "/".join(p for p in name.split("/")
                           if not p.startswith(("jit", "while", "checkpoint",
                                                "remat", "body",
                                                "closed_call")))[:110]
            agg[(op, tag)] = (agg.get((op, tag), 0.0)
                              + n * _DTYPE_BYTES.get(dt, 4)
                              * mult.get(cur, 1.0))
    return sorted(((b, op, tag) for (op, tag), b in agg.items()),
                  reverse=True)[:top]
