import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, proving the distribution config is coherent without
hardware.  MUST be run as a script or via ``run_cell`` in a fresh process —
the XLA_FLAGS line above executes before any jax import.

Per cell this reports:
  - compile success,
  - memory_analysis (bytes per device -> fits 16 GB v5e HBM?),
  - cost_analysis (FLOPs / bytes for the roofline),
  - collective bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single --out /tmp/cell.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import json
import sys


# Per-arch microbatch counts for train_4k (activation-memory fit on 16 GB).
TRAIN_MICROBATCHES = {
    "command-r-plus-104b": 8,
    "phi3.5-moe-42b-a6.6b": 4,
    "deepseek-v2-lite-16b": 2,
    "falcon-mamba-7b": 2,
}

def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             parse_collectives: bool = True, verbose: bool = True) -> dict:
    import jax
    from repro.configs import SHAPES, cell_runnable, get
    from repro.launch import sharding as shr
    from repro.launch import specs as specs_mod
    from repro.launch.mesh import make_production_mesh
    from repro.models import common, get_model
    from repro.optim import adamw
    from repro.train.train_step import make_train_step

    cfg = get(arch_name)
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg, shape)
    result = {"arch": arch_name, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        result.update(status="skip", reason=why)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    common.set_mesh(mesh)
    sp = specs_mod.input_specs(arch_name, shape)
    params_sh = shr.params_shardings(sp["params"], mesh)
    batch_sh = shr.batch_shardings(sp["batch"], mesh, shape.kind)

    if shape.kind == "train":
        mb = TRAIN_MICROBATCHES.get(arch_name, 1)
        opt_cfg = adamw.OptConfig()
        step = make_train_step(cfg, opt_cfg, microbatches=mb)
        opt_sh = shr.opt_shardings(sp["opt"], params_sh, mesh)
        fn = jax.jit(step,
                     in_shardings=(params_sh, opt_sh, None, batch_sh),
                     out_shardings=(params_sh, opt_sh, None, None))
        args = (sp["params"], sp["opt"], None, sp["batch"])
        result["microbatches"] = mb
    elif shape.kind == "decode":
        model = get_model(cfg)
        cache_sh = shr.cache_shardings(sp["cache"], mesh)

        def serve_step(params, cache, batch):
            return model.decode_step(params, cache, batch)
        fn = jax.jit(serve_step,
                     in_shardings=(params_sh, cache_sh, batch_sh),
                     out_shardings=(None, cache_sh))
        args = (sp["params"], sp["cache"], sp["batch"])
    else:  # prefill
        model = get_model(cfg)

        def prefill_step(params, batch):
            return model.prefill(params, batch)
        fn = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh),
                     out_shardings=None)
        args = (sp["params"], sp["batch"])

    import time
    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    result.update(status="ok", lower_s=round(t1 - t0, 1),
                  compile_s=round(t2 - t1, 1))

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
        n_dev = mesh.size
        args_b = result.get("argument_size_in_bytes", 0)
        temp_b = result.get("temp_size_in_bytes", 0)
        result["bytes_per_device"] = int(args_b + temp_b)
        result["fits_16g"] = bool(result["bytes_per_device"] < 16e9)
        del n_dev
    cost = compiled.cost_analysis()
    if cost:
        c = cost[0] if isinstance(cost, (list, tuple)) else cost
        result["flops"] = float(c.get("flops", -1))
        result["bytes_accessed"] = float(c.get("bytes accessed", -1))
        result["transcendentals"] = float(c.get("transcendentals", 0))
    if parse_collectives:
        try:
            from repro.launch import hlo_cost
            txt = compiled.as_text()
            result["hlo_chars"] = len(txt)
            hc = hlo_cost.analyze(txt)
            result["collectives"] = {
                k: hc[k] for k in ("all-gather", "all-reduce",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute",
                                   "collective_bytes", "collective_count")}
            result["dot_flops_loop_corrected"] = hc["dot_flops"]
            result["bytes_loop_corrected"] = hc["bytes_accessed"]
            del txt
        except Exception as e:  # pragma: no cover
            result["collectives_error"] = str(e)
    if verbose:
        print(json.dumps(result, indent=1), flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-collectives", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, SHAPES
    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCHS for s in SHAPES])
    results = []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, args.mesh == "multi",
                                    not args.no_collectives))
        except Exception as e:
            results.append({"arch": arch, "shape": shape,
                            "status": "error", "error": repr(e)[:500]})
            print(results[-1], file=sys.stderr, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
