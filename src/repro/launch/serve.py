"""Serving launcher CLI: batched generation with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --reduced --requests 8 --max-new 12
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get
from repro.models import get_model
from repro.serve import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 6)),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    engine.run(reqs)
    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests "
          f"({args.slots} slots, continuous batching)")
    for r in reqs[:3]:
        print("  out:", r.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
