"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod = 256 chips as (16 data x 16 model);
multi-pod adds a leading pure-DP "pod" axis (2 x 16 x 16 = 512 chips).
Gradient all-reduce crosses the pod axis (DCN on real hardware) — the
gradient-compression hook in optim/adamw.py targets exactly that traffic.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-size sharding tests (requires >= n_data*n_model
    host devices, e.g. via XLA_FLAGS=--xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
