"""Parameter / optimizer / batch / cache sharding rules.

2-D sharding ("tensor parallel" on the model axis + FSDP on the data axis):
for every parameter we pick the model-parallel dimension by name-aware rules
with divisibility-aware degradation (models.common), and FSDP-shard a second
dimension.  Optimizer moments/master mirror the parameter specs, so the full
AdamW state for command-r-plus-104b (~1.3 TB in f32) spreads over all 256
chips (~5 GB each) — the ZeRO-3 requirement for v5e (16 GB HBM).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common

# Leaf-name -> (dim roles) AFTER stripping a leading stacked-layer dim.
# Roles: "m" = model axis, "f" = fsdp(data) axis, "-" = replicated.
_RULES_2D = {
    "wq": "fm", "wk": "fm", "wv": "fm", "wo": "mf",
    "wi": "fm", "wg": "fm",
    "in_proj": "fm", "in_x": "fm", "in_z": "fm",
    "out_proj": "mf", "x_proj": "m-", "dt_proj": "-m",
    "wa": "mf", "wx": "mf",
    "wdkv": "f-", "wkr": "f-", "wuk": "-m", "wuv": "-m",
    "router": "--",
    "embed": "mf", "lm_head": "fm",
    "conv_w": "-m", "a_log": "m-",
}
_RULES_3D = {          # MoE expert-stacked weights (E, d, f) / (E, f, d)
    "wi": "mf-", "wg": "mf-", "wo": "m-f",
}
_ROLE_AXIS = {"m": common.MODEL, "f": common.FSDP, "-": None}


def _leaf_spec(path, leaf, mesh) -> NamedSharding:
    names = [str(getattr(p, "key", "")) for p in path]
    name = names[-1] if names else ""
    shape = leaf.shape
    stacked = any(n in ("blocks", "dense_blocks", "encoder", "decoder")
                  for n in names)
    core = shape[1:] if stacked and len(shape) > 1 else shape
    prefix = [None] * (len(shape) - len(core))

    roles = None
    if len(core) == 3 and name in _RULES_3D:
        roles = _RULES_3D[name]
    elif len(core) == len(_RULES_2D.get(name, "")) and name in _RULES_2D:
        roles = _RULES_2D[name]

    if roles is not None:
        dims = prefix + [_ROLE_AXIS[r] for r in roles]
    elif len(core) == 1 and core[0] >= 2048:
        dims = prefix + [common.MODEL]          # large vectors (d_skip, ...)
    elif len(core) >= 2:
        # Fallback heuristic: model on the last dim, fsdp on the first.
        dims = prefix + [common.FSDP] + [None] * (len(core) - 2) \
            + [common.MODEL]
    else:
        dims = prefix + [None] * len(core)
    return common.named_sharding(mesh, shape, *dims)


def params_shardings(params_shape, mesh):
    """Pytree of NamedShardings matching a params (or eval_shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = [_leaf_spec(path, leaf, mesh) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(opt_shape, params_sh, mesh):
    """Optimizer state mirrors parameter shardings (step replicated)."""
    return {
        "step": NamedSharding(mesh, P()),
        "m": params_sh, "v": params_sh, "master": params_sh,
    }


def batch_shardings(batch_shape, mesh, kind: str = "train"):
    if kind == "decode":
        # serving layout: single-token batch replicated (see
        # models.common.set_decode_layout)
        return {k: NamedSharding(mesh, P()) for k in batch_shape}
    out = {}
    for k, v in batch_shape.items():
        if k == "positions3":
            out[k] = common.named_sharding(mesh, v.shape, None, common.BATCH,
                                           None)
        elif k in ("vision_embeds", "audio_embeds"):
            out[k] = common.named_sharding(mesh, v.shape, common.BATCH, None,
                                           None)
        else:
            out[k] = common.named_sharding(
                mesh, v.shape, *([common.BATCH] + [None] * (v.ndim - 1)))
    return out


def cache_shardings(cache_shape, mesh):
    """Serving cache: batch->data; long axes (seq / d_inner) -> model."""
    rules = {
        "k": (None, common.BATCH, common.MODEL, None, None),
        "v": (None, common.BATCH, common.MODEL, None, None),
        "ek": (None, common.BATCH, None, common.MODEL, None),
        "ev": (None, common.BATCH, None, common.MODEL, None),
        "c": (None, common.BATCH, common.MODEL, None),
        "kr": (None, common.BATCH, common.MODEL, None),
        "conv": (None, common.BATCH, None, common.MODEL),
        "h": None,  # rank differs: ssm (L,B,din,n) vs hybrid (L,B,w)
    }
    out = {}
    for k, v in cache_shape.items():
        if k == "h":
            dims = ((None, common.BATCH, common.MODEL, None) if v.ndim == 4
                    else (None, common.BATCH, common.MODEL))
        else:
            dims = rules[k]
        out[k] = common.named_sharding(mesh, v.shape, *dims)
    return out
