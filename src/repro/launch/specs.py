"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Follows the assignment contract: specs are weak-type-correct, shardable
stand-ins — no device allocation.  Modality frontends are stubs: the specs
*are* the precomputed frame/patch embeddings the stub would produce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get
from repro.models import get_model
from repro.optim import adamw

I32 = jnp.int32
BF16 = jnp.bfloat16
S = jax.ShapeDtypeStruct


def batch_specs(arch_name: str, shape: ShapeConfig) -> dict:
    cfg = get(arch_name)
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    out = {"tokens": S((b, s), I32), "positions": S((b, s), I32)}
    if shape.kind == "train":
        out["targets"] = S((b, s), I32)
    if cfg.positional == "mrope":
        out["positions3"] = S((3, b, s), I32)
    if cfg.frontend == "vision" and shape.kind != "decode":
        out["vision_embeds"] = S((b, s, cfg.d_model), BF16)
        out["vision_mask"] = S((b, s), jnp.bool_)
    if cfg.encoder_decoder and shape.kind != "decode":
        out["audio_embeds"] = S((b, cfg.encoder_seq, cfg.d_model), BF16)
    return out


def state_specs(arch_name: str):
    """(params, opt, err) ShapeDtypeStruct pytrees via eval_shape."""
    cfg = get(arch_name)
    model = get_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(adamw.init_state, params)
    return params, opt


def cache_specs(arch_name: str, shape: ShapeConfig):
    cfg = get(arch_name)
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def input_specs(arch_name: str, shape_name_or_cfg) -> dict:
    """All specs for one cell: train -> params/opt/batch; decode ->
    params/cache/batch."""
    from repro.configs import SHAPES
    shape = (SHAPES[shape_name_or_cfg]
             if isinstance(shape_name_or_cfg, str) else shape_name_or_cfg)
    params, opt = state_specs(arch_name)
    out = {"batch": batch_specs(arch_name, shape), "params": params}
    if shape.kind == "train":
        out["opt"] = opt
    if shape.kind == "decode":
        out["cache"] = cache_specs(arch_name, shape)
    return out
