"""Functional RVV-lite interpreters (numpy test oracles).

Two execution modes:

  * :func:`run` — conventional full VRF: 32 physical vector registers.
  * :func:`run_dispersed` — the paper's mechanism operating on *data*:
    ``capacity`` physical registers + a pinned ``v0`` + the reserved spill
    region inside simulated memory.  Misses trigger actual spill/fill data
    movement exactly as §3.2 describes.

Register Dispersion must be **semantics-preserving**: for any program and any
capacity >= 3 (three operands must be co-resident), ``run_dispersed`` must
produce bit-identical memory/registers to ``run``.  Property tests in
``tests/test_property_dispersion.py`` check this on random programs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import events as ev_mod
from repro.core import isa, policies
from repro.core.trace import Program

VL = isa.VL_ELEMS


@dataclasses.dataclass
class RunResult:
    memory: np.ndarray              # final memory image (f32 words)
    vregs: np.ndarray               # (32, VL) final architectural registers
    vrf_hits: int = 0
    vrf_misses: int = 0
    spills: int = 0
    fills: int = 0


def _exec_op(op, vd_val, vs1_val, vs2_val, imm, mask):
    """Pure f32 semantics of one vector instruction. Returns new vd value
    (or None) and new mask (or None)."""
    f = np.float32
    if op == isa.VADD:
        return vs1_val + vs2_val, None
    if op == isa.VSUB:
        return vs1_val - vs2_val, None
    if op == isa.VMUL:
        return vs1_val * vs2_val, None
    if op == isa.VDIV:
        with np.errstate(divide="ignore", invalid="ignore"):
            return (vs1_val / vs2_val).astype(f), None
    if op == isa.VSQRT:
        with np.errstate(invalid="ignore"):
            return np.sqrt(vs1_val).astype(f), None
    if op == isa.VFMA:
        return (vd_val + vs1_val * vs2_val).astype(f), None
    if op == isa.VMAX:
        return np.maximum(vs1_val, vs2_val), None
    if op == isa.VMIN:
        return np.minimum(vs1_val, vs2_val), None
    if op == isa.VREDSUM:
        out = np.zeros(VL, f)
        out[0] = f(vs1_val[0]) + vs2_val.astype(np.float64).sum().astype(f)
        return out, None
    if op == isa.VREDMAX:
        out = np.zeros(VL, f)
        out[0] = max(f(vs1_val[0]), vs2_val.max())
        return out, None
    if op == isa.VMVV:
        return vs1_val.copy(), None
    if op == isa.VCMPLT:
        return None, (vs1_val < vs2_val).astype(f)
    if op == isa.VMERGE:
        return np.where(mask > 0, vs1_val, vs2_val).astype(f), None
    if op == isa.VSLIDE1DN:
        return np.concatenate([vs1_val[1:], [f(imm)]]).astype(f), None
    if op == isa.VSLIDE1UP:
        return np.concatenate([[f(imm)], vs1_val[:-1]]).astype(f), None
    if op == isa.VXOR:
        a = vs1_val.view(np.int32) ^ vs2_val.view(np.int32)
        return a.view(f).copy(), None
    if op == isa.VMULSC:
        return (vs1_val * f(imm)).astype(f), None
    if op == isa.VADDSC:
        return (vs1_val + f(imm)).astype(f), None
    raise ValueError(f"unhandled op {op}")


def run(program: Program) -> RunResult:
    """Full-VRF functional execution."""
    mem = program.memory.copy()
    regs = np.zeros((isa.NUM_ARCH_VREGS, VL), np.float32)
    for i in range(program.num_instructions):
        op = int(program.op[i])
        if op == isa.SCALAR:
            continue
        vd, vs1, vs2 = (int(program.vd[i]), int(program.vs1[i]),
                        int(program.vs2[i]))
        addr, imm = int(program.addr[i]), float(program.imm[i])
        if op == isa.VLE:
            regs[vd] = mem[addr // 4: addr // 4 + VL]
        elif op == isa.VSE:
            mem[addr // 4: addr // 4 + VL] = regs[vs1]
        elif op == isa.VSES:
            mem[addr // 4] = regs[vs1][0]
        elif op == isa.VBCAST:
            regs[vd] = mem[addr // 4]
        else:
            vd_val = regs[vd] if vd >= 0 else None
            res, new_mask = _exec_op(
                op, vd_val, regs[vs1] if vs1 >= 0 else None,
                regs[vs2] if vs2 >= 0 else None, imm, regs[isa.MASK_REG])
            if new_mask is not None:
                regs[isa.MASK_REG] = new_mask
            elif res is not None:
                regs[vd] = res
    return RunResult(memory=mem, vregs=regs)


class _DispersedRF:
    """Data-holding cVRF: capacity physical slots + pinned v0 + spill region."""

    def __init__(self, capacity: int, policy: int, mem: np.ndarray,
                 spill_word0: int):
        self.capacity = capacity
        self.policy = policy
        self.mem = mem
        self.spill_word0 = spill_word0           # f32-word index of v1's home
        self.phys = np.zeros((capacity, VL), np.float32)
        self.tags = np.full(capacity, -1, np.int64)
        self.dirty = np.zeros(capacity, bool)
        self.ins_seq = np.zeros(capacity, np.int64)
        self.last_use = np.zeros(capacity, np.int64)
        self.freq = np.zeros(capacity, np.int64)
        self.next_use = np.zeros(capacity, np.int64)
        self.pinned = np.zeros(capacity, bool)
        self.v0 = np.zeros(VL, np.float32)       # dedicated mask register
        self.seq = 0
        self.now = 0
        self.hits = self.misses = self.spills = self.fills = 0

    def _home(self, reg: int) -> int:
        assert reg >= 1
        return self.spill_word0 + (reg - 1) * VL

    def access(self, reg: int, *, write: bool, read: bool,
               next_use: int = 0, locked=()) -> int:
        """Bring ``reg`` into the physical file; returns its slot index."""
        self.now += 1
        if reg == isa.MASK_REG:
            return -1                             # pinned, handled separately
        where = np.nonzero(self.tags == reg)[0]
        if where.size:
            s = int(where[0])
            self.hits += 1
            self.last_use[s] = self.now
            self.freq[s] += 1
            self.next_use[s] = next_use
            self.dirty[s] |= write
            return s
        self.misses += 1
        free = np.nonzero(self.tags < 0)[0]
        if free.size:
            s = int(free[0])
        else:
            s = policies.np_select_victim(
                self.tags, self.ins_seq, self.last_use, self.freq,
                self.next_use, self.pinned, self.capacity, self.policy,
                locked=locked)
            if self.dirty[s]:                     # spill evictee to its home
                h = self._home(int(self.tags[s]))
                self.mem[h: h + VL] = self.phys[s]
                self.spills += 1
        # Fill from the reserved address (the paper always fetches; a value
        # that was never spilled reads the zero-initialised home location,
        # matching the zero-initialised registers of ``run``).
        h = self._home(reg)
        self.phys[s] = self.mem[h: h + VL]
        self.fills += 1
        self.tags[s] = reg
        self.dirty[s] = write
        self.seq += 1
        self.ins_seq[s] = self.seq
        self.last_use[s] = self.now
        self.freq[s] = 1
        self.next_use[s] = next_use
        return s


def run_dispersed(program: Program, capacity: int,
                  policy: int = policies.FIFO) -> RunResult:
    """Register-Dispersion execution: semantics must match :func:`run`.

    For OPT the interpreter runs a Belady pre-pass
    (:func:`repro.core.events.next_use_grid`): every register access carries
    the grid index of that register's next use, in the same (T, 3) slot
    index space the fused engine scans, so both engines' farthest-next-use
    victim choices — and therefore the differential counters — agree
    bit-for-bit.
    """
    if capacity < 3:
        raise ValueError("cVRF must hold at least 3 registers (3 operands)")
    spill_bytes = (isa.NUM_ARCH_VREGS - 1) * isa.VLEN_BYTES
    base = program.memory.size * 4
    base = (base + isa.VLEN_BYTES - 1) // isa.VLEN_BYTES * isa.VLEN_BYTES
    mem = np.zeros((base + spill_bytes) // 4, np.float32)
    mem[: program.memory.size] = program.memory
    rf = _DispersedRF(capacity, policy, mem, base // 4)

    # Belady pre-pass: OPT needs each access's next-use index; the other
    # policies ignore it (the accessor stores it but never reads it back).
    nxt = (ev_mod.next_use_grid(program) if policy == policies.OPT
           else np.zeros((program.num_instructions, 3), np.int32))

    tbl = isa.op_table()
    for i in range(program.num_instructions):
        op = int(program.op[i])
        if op == isa.SCALAR:
            continue
        vd, vs1, vs2 = (int(program.vd[i]), int(program.vs1[i]),
                        int(program.vs2[i]))
        addr, imm = int(program.addr[i]), float(program.imm[i])

        def val(reg, slot):
            return rf.v0 if reg == isa.MASK_REG else rf.phys[slot]

        s1 = (rf.access(vs1, write=False, read=True,
                        next_use=int(nxt[i, 0]))
              if tbl["reads_vs1"][op] and vs1 >= 0 else -1)
        s2 = (rf.access(vs2, write=False, read=True, locked=(vs1,),
                        next_use=int(nxt[i, 1]))
              if tbl["reads_vs2"][op] and vs2 >= 0 else -1)
        sd = -1
        if (tbl["reads_vd"][op] or tbl["writes_vd"][op]) and vd >= 0:
            sd = rf.access(vd, write=bool(tbl["writes_vd"][op]),
                           read=bool(tbl["reads_vd"][op]),
                           locked=(vs1, vs2), next_use=int(nxt[i, 2]))

        if op == isa.VLE:
            out = rf.mem[addr // 4: addr // 4 + VL].copy()
            if vd == isa.MASK_REG:
                rf.v0 = out
            else:
                rf.phys[sd] = out
        elif op == isa.VSE:
            rf.mem[addr // 4: addr // 4 + VL] = val(vs1, s1)
        elif op == isa.VSES:
            rf.mem[addr // 4] = val(vs1, s1)[0]
        elif op == isa.VBCAST:
            out = np.full(VL, rf.mem[addr // 4], np.float32)
            if vd == isa.MASK_REG:
                rf.v0 = out
            else:
                rf.phys[sd] = out
        else:
            res, new_mask = _exec_op(
                op,
                val(vd, sd) if vd >= 0 else None,
                val(vs1, s1) if vs1 >= 0 else None,
                val(vs2, s2) if vs2 >= 0 else None,
                imm, rf.v0)
            if new_mask is not None:
                rf.v0 = new_mask
            elif res is not None:
                if vd == isa.MASK_REG:
                    rf.v0 = res
                else:
                    rf.phys[sd] = res

    # Reconstruct the architectural register file for comparison: cached
    # registers from the cVRF, everything else from its home address.
    vregs = np.zeros((isa.NUM_ARCH_VREGS, VL), np.float32)
    for r in range(1, isa.NUM_ARCH_VREGS):
        h = rf._home(r)
        vregs[r] = rf.mem[h: h + VL]
    for s in range(capacity):
        if rf.tags[s] >= 0:
            vregs[int(rf.tags[s])] = rf.phys[s]
    vregs[isa.MASK_REG] = rf.v0
    return RunResult(memory=rf.mem[: program.memory.size], vregs=vregs,
                     vrf_hits=rf.hits, vrf_misses=rf.misses,
                     spills=rf.spills, fills=rf.fills)
