"""Working-set planning on top of the cycle simulator.

Turns the paper's Fig 5 analysis into an API: given a kernel's trace, find
the minimum cVRF capacity achieving a target hit rate (the paper uses >95%),
and quantify the headroom of smarter replacement policies (beyond-paper).
The same planner sizes the serving layer's dispersed KV cache.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import policies, simulator
from repro.core.trace import Program


@dataclasses.dataclass
class PlanResult:
    min_capacity: int
    hit_rates: dict[int, float]            # capacity -> hit rate
    cycles: dict[int, int]                 # capacity -> cycles
    full_vrf_cycles: int
    active_regs: int


def min_registers_for_hit_rate(
    program: Program,
    target: float = 0.95,
    capacities=tuple(range(3, 17)),
    policy: int = policies.FIFO,
    machine: simulator.MachineParams = simulator.DEFAULT_MACHINE,
    max_events: int | None = None,
    fold: bool = False,
) -> PlanResult:
    """Smallest capacity whose operand hit rate exceeds ``target``.

    ``program`` may be a Program, a pre-expanded EventStream, or a
    PreparedTrace (e.g. the benchmark layer's folded cache entry).
    """
    prep = simulator.prepare(program, fold=fold, max_events=max_events,
                             machine=machine)
    caps = list(capacities) + [32]
    sweep = simulator.SweepConfig.make(caps, policy)
    out = simulator.simulate_grid([prep], sweep, machine)
    hit = {c: float(h) for c, h in zip(caps, out["hit_rate"][0])}
    cyc = {c: int(x) for c, x in zip(caps, out["cycles"][0])}
    ok = [c for c in capacities if hit[c] > target]
    active = (len(program.active_vregs())
              if isinstance(program, Program) else -1)
    return PlanResult(
        min_capacity=min(ok) if ok else max(capacities) + 1,
        hit_rates=hit, cycles=cyc, full_vrf_cycles=cyc[32],
        active_regs=active,
    )


def policy_headroom(program: Program, capacities=tuple(range(3, 9)),
                    max_events: int | None = None,
                    fold: bool = False) -> dict:
    """Hit-rate comparison FIFO vs LRU vs LFU vs OPT (beyond-paper study).

    OPT (Belady) upper-bounds any realizable policy; the gap FIFO->OPT is the
    headroom the paper left on the table by choosing the cheapest policy.
    One grid call sweeps the full capacities x policies product.
    """
    prep = simulator.prepare(program, fold=fold, max_events=max_events)
    pols = (policies.FIFO, policies.LRU, policies.LFU, policies.OPT)
    sweep = simulator.SweepConfig.product(list(capacities), pols)
    res = simulator.simulate_grid([prep], sweep)
    out = {}
    for li, pol in enumerate(pols):
        out[policies.POLICY_NAMES[pol]] = {
            int(c): float(res["hit_rate"][0, ci * len(pols) + li])
            for ci, c in enumerate(capacities)}
    return out


def normalized_performance(program: Program, capacities,
                           policy: int = policies.FIFO,
                           max_events: int | None = None) -> dict[int, float]:
    """Fig 4(a): performance of each capacity normalized to the full VRF
    (1.0 = no slowdown; <1.0 = dispersion stalls hurt)."""
    caps = list(capacities) + [32]
    sweep = simulator.SweepConfig.make(caps, policy)
    prep = simulator.prepare(program, max_events=max_events)
    out = simulator.simulate_grid([prep], sweep)
    full = float(out["cycles"][0, -1])
    return {int(c): full / float(x)
            for c, x in zip(caps[:-1], out["cycles"][0, :-1])}
