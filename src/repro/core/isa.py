"""RVV-lite instruction set used by the Register Dispersion simulator.

The paper targets the RISC-V "V" extension on a 3-stage in-order core with a
256-bit / 8-lane VPU (Table 1).  We model the subset of RVV that the paper's
benchmark suite (Table 2) exercises, at the granularity the cVRF mechanism
cares about: which *architectural vector registers* each instruction reads and
writes, whether the destination is also a source (``vmacc``/``vmadd``-style),
whether the instruction is masked (reads the pinned ``v0``), and its memory
behaviour.

Vector length: VL = 256 bits = 8 x f32 elements = one 32-byte cacheline, per
the paper's constraint that VL never exceeds the cacheline size so a vector
load is a single micro-op.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ----------------------------------------------------------------------------
# Machine parameters (Table 1 of the paper).
# ----------------------------------------------------------------------------
VLEN_BITS = 256
ELEM_BITS = 32
VL_ELEMS = VLEN_BITS // ELEM_BITS            # 8 f32 elements per vector reg
VLEN_BYTES = VLEN_BITS // 8                  # 32 bytes = one cacheline
NUM_ARCH_VREGS = 32                          # RVV mandates 32 architectural regs
MASK_REG = 0                                 # v0: pinned, never dispersed

# ----------------------------------------------------------------------------
# Opcodes.
# ----------------------------------------------------------------------------
SCALAR = 0        # scalar bookkeeping (loop counters, pointer bumps, branches)
VLE = 1           # unit-stride vector load   vd <- mem[addr : addr+32]
VSE = 2           # unit-stride vector store  mem[addr : addr+32] <- vs1
VADD = 3          # vd = vs1 + vs2
VSUB = 4          # vd = vs1 - vs2
VMUL = 5          # vd = vs1 * vs2
VDIV = 6          # vd = vs1 / vs2
VSQRT = 7         # vd = sqrt(vs1)
VFMA = 8          # vd = vd + vs1 * vs2      (vmacc: destination is a source)
VMAX = 9          # vd = max(vs1, vs2)
VMIN = 10         # vd = min(vs1, vs2)
VREDSUM = 11      # vd[0] = vs1[0] + sum(vs2)  (reads vs1 seed; writes vd)
VREDMAX = 12      # vd[0] = max(vs1[0], max(vs2))
VBCAST = 13       # vd = broadcast(mem_scalar[addr])   (flw + vfmv.v.f macro)
VMVV = 14         # vd = vs1                  (vmv.v.v register move)
VCMPLT = 15       # v0 = (vs1 < vs2)          (writes the pinned mask register)
VMERGE = 16       # vd = v0 ? vs1 : vs2       (masked merge; reads v0)
VSLIDE1DN = 17    # vd = {vs1[1:], x}         (slide down one element)
VSLIDE1UP = 18    # vd = {x, vs1[:-1]}        (slide up one element)
VXOR = 19         # vd = bitwise-ish xor (modelled on f32 lanes as a*0+b style)
VMULSC = 20       # vd = vs1 * scalar_imm     (vector-scalar multiply)
VADDSC = 21       # vd = vs1 + scalar_imm
VSES = 22         # mem[addr] <- vs1[0]        (vfmv.f.s + fsw macro, 4 bytes)

NUM_OPS = 23

OP_NAMES = {
    SCALAR: "scalar", VLE: "vle", VSE: "vse", VADD: "vadd", VSUB: "vsub",
    VMUL: "vmul", VDIV: "vdiv", VSQRT: "vsqrt", VFMA: "vmacc", VMAX: "vmax",
    VMIN: "vmin", VREDSUM: "vredsum", VREDMAX: "vredmax", VBCAST: "vbcast",
    VMVV: "vmv.v.v", VCMPLT: "vmslt", VMERGE: "vmerge", VSLIDE1DN: "vslide1dn",
    VSLIDE1UP: "vslide1up", VXOR: "vxor", VMULSC: "vmul.vx", VADDSC: "vadd.vx",
}


@dataclasses.dataclass(frozen=True)
class OpInfo:
    """Static metadata for one opcode.

    reads_vs1 / reads_vs2: whether the vs1/vs2 fields name live register reads.
    reads_vd:  destination-is-source (vmacc/vmadd/vmerge family).
    writes_vd: instruction produces a vector register result.
    writes_mask: result goes to the pinned v0 instead of a cVRF-managed reg.
    full_overwrite: vd is fully overwritten (no fetch needed on a vd miss when
        the allocate-no-fetch optimisation is enabled; the paper always
        fetches, so this only matters for the beyond-paper policy flag).
    is_load / is_store: unit-stride vector memory op touching ``addr``.
    cost: base occupancy cycles on the 8-lane VPU (1 for most ops; division,
        sqrt and reductions are multi-cycle on low-cost implementations).
    """

    reads_vs1: bool = False
    reads_vs2: bool = False
    reads_vd: bool = False
    writes_vd: bool = True
    writes_mask: bool = False
    full_overwrite: bool = True
    is_load: bool = False
    is_store: bool = False
    cost: int = 1


OP_INFO: dict[int, OpInfo] = {
    SCALAR: OpInfo(writes_vd=False, full_overwrite=False, cost=1),
    VLE: OpInfo(is_load=True, cost=1),
    VSE: OpInfo(reads_vs1=True, writes_vd=False, full_overwrite=False,
                is_store=True, cost=1),
    VADD: OpInfo(reads_vs1=True, reads_vs2=True),
    VSUB: OpInfo(reads_vs1=True, reads_vs2=True),
    VMUL: OpInfo(reads_vs1=True, reads_vs2=True),
    VDIV: OpInfo(reads_vs1=True, reads_vs2=True, cost=8),
    VSQRT: OpInfo(reads_vs1=True, cost=8),
    VFMA: OpInfo(reads_vs1=True, reads_vs2=True, reads_vd=True,
                 full_overwrite=False),
    VMAX: OpInfo(reads_vs1=True, reads_vs2=True),
    VMIN: OpInfo(reads_vs1=True, reads_vs2=True),
    VREDSUM: OpInfo(reads_vs1=True, reads_vs2=True, cost=4),
    VREDMAX: OpInfo(reads_vs1=True, reads_vs2=True, cost=4),
    VBCAST: OpInfo(is_load=True, cost=2),        # scalar load + broadcast
    VMVV: OpInfo(reads_vs1=True),
    VCMPLT: OpInfo(reads_vs1=True, reads_vs2=True, writes_vd=False,
                   writes_mask=True, full_overwrite=False),
    VMERGE: OpInfo(reads_vs1=True, reads_vs2=True),    # also reads v0 (pinned)
    VSLIDE1DN: OpInfo(reads_vs1=True),
    VSLIDE1UP: OpInfo(reads_vs1=True),
    VXOR: OpInfo(reads_vs1=True, reads_vs2=True),
    VMULSC: OpInfo(reads_vs1=True),
    VADDSC: OpInfo(reads_vs1=True),
    VSES: OpInfo(reads_vs1=True, writes_vd=False, full_overwrite=False,
                 is_store=True, cost=2),
}

MASK_READERS = {VMERGE}        # ops that read v0 as an implicit operand


def op_table() -> dict[str, np.ndarray]:
    """Dense per-opcode metadata tables indexed by opcode (for the simulator)."""
    n = NUM_OPS
    tbl = {
        "reads_vs1": np.zeros(n, np.bool_),
        "reads_vs2": np.zeros(n, np.bool_),
        "reads_vd": np.zeros(n, np.bool_),
        "writes_vd": np.zeros(n, np.bool_),
        "writes_mask": np.zeros(n, np.bool_),
        "full_overwrite": np.zeros(n, np.bool_),
        "is_load": np.zeros(n, np.bool_),
        "is_store": np.zeros(n, np.bool_),
        "cost": np.zeros(n, np.int32),
    }
    for op, info in OP_INFO.items():
        tbl["reads_vs1"][op] = info.reads_vs1
        tbl["reads_vs2"][op] = info.reads_vs2
        tbl["reads_vd"][op] = info.reads_vd
        tbl["writes_vd"][op] = info.writes_vd
        tbl["writes_mask"][op] = info.writes_mask
        tbl["full_overwrite"][op] = info.full_overwrite
        tbl["is_load"][op] = info.is_load
        tbl["is_store"][op] = info.is_store
        tbl["cost"][op] = info.cost
    return tbl
