"""Instruction trace -> operand/memory event stream.

The Register Dispersion hardware checks the (up to three) vector operands of
an instruction *serially* in the ID stage (paper §3.2.1), then accesses the
data cache in EX for vector loads/stores.  We therefore simulate at *event*
granularity: each instruction expands to

    [REG vs1?] [REG vs2?] [REG vd?] [MEM line0?] [MEM line1?] | [SCALAR]

which makes the cycle model a uniform ``lax.scan`` over one flat stream and
naturally reproduces the serialized miss handling of the hardware.

``v0`` (the RVV mask register) is pinned in a dedicated register and never
generates cVRF events (paper §3.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa
from repro.core.trace import Program

K_SCALAR = 0
K_REG = 1
K_MEM = 2

NO_NEXT_USE = np.int32(2**31 - 8)


@dataclasses.dataclass
class EventStream:
    kind: np.ndarray        # (E,) int8
    reg: np.ndarray         # (E,) int32  (REG events; -1 otherwise)
    line: np.ndarray        # (E,) int64  cacheline index (MEM events)
    is_write: np.ndarray    # (E,) bool
    needs_read: np.ndarray  # (E,) bool   (REG: value must be fetched on miss)
    no_fetch_ok: np.ndarray  # (E,) bool  (REG: full overwrite, fetch skippable)
    cost: np.ndarray        # (E,) int32  base cycles charged on this event
    next_use: np.ndarray    # (E,) int32  next event index touching same reg
    lock_a: np.ndarray      # (E,) int32  operand already checked -> not evictable
    lock_b: np.ndarray      # (E,) int32  second locked operand (-1 if none)
    spill_line0: int        # first cacheline of the reserved vreg spill region
    num_instructions: int

    @property
    def num_events(self) -> int:
        return int(self.kind.shape[0])


def expand(program: Program) -> EventStream:
    """Vectorised numpy expansion of an instruction trace into events."""
    tbl = isa.op_table()
    op = program.op
    T = op.shape[0]
    vd, vs1, vs2 = program.vd, program.vs1, program.vs2
    addr = program.addr

    r_vs1 = tbl["reads_vs1"][op]
    r_vs2 = tbl["reads_vs2"][op]
    r_vd = tbl["reads_vd"][op]
    w_vd = tbl["writes_vd"][op]
    full_ow = tbl["full_overwrite"][op]
    is_load = tbl["is_load"][op]
    is_store = tbl["is_store"][op]
    base_cost = np.where(program.cost_override >= 0, program.cost_override,
                         tbl["cost"][op]).astype(np.int32)

    mask_reg = isa.MASK_REG
    # Per-instruction event slots (order = hardware order).
    S = 6
    valid = np.zeros((T, S), np.bool_)
    kind = np.zeros((T, S), np.int8)
    reg = np.full((T, S), -1, np.int32)
    line = np.full((T, S), -1, np.int64)
    is_write = np.zeros((T, S), np.bool_)
    needs_read = np.zeros((T, S), np.bool_)
    no_fetch = np.zeros((T, S), np.bool_)
    lock_a = np.full((T, S), -1, np.int32)
    lock_b = np.full((T, S), -1, np.int32)

    # slot 0/1: vs1 / vs2 reads.
    for s, (r_flag, rs) in enumerate(((r_vs1, vs1), (r_vs2, vs2))):
        v = r_flag & (rs >= 0) & (rs != mask_reg)
        valid[:, s] = v
        kind[:, s] = K_REG
        reg[:, s] = rs
        needs_read[:, s] = True
    # Serial tag check (paper 3.2.1): vs2's miss handling must not evict the
    # already-resolved vs1; vd's must not evict vs1 or vs2.
    lock_a[:, 1] = np.where(valid[:, 0], vs1, -1)
    # slot 2: vd access (read and/or write).
    v = (r_vd | w_vd) & (vd >= 0) & (vd != mask_reg)
    valid[:, 2] = v
    kind[:, 2] = K_REG
    reg[:, 2] = vd
    is_write[:, 2] = w_vd
    needs_read[:, 2] = r_vd
    no_fetch[:, 2] = full_ow & w_vd & ~r_vd
    lock_a[:, 2] = np.where(valid[:, 0], vs1, -1)
    lock_b[:, 2] = np.where(valid[:, 1], vs2, -1)
    # slot 3/4: data-cache lines touched by vector loads/stores.
    is_mem = is_load | is_store
    nbytes = np.where((op == isa.VBCAST) | (op == isa.VSES), 4,
                  isa.VLEN_BYTES)
    line0 = addr >> 5
    line1 = (addr + nbytes - 1) >> 5
    valid[:, 3] = is_mem
    kind[:, 3] = K_MEM
    line[:, 3] = line0
    is_write[:, 3] = is_store
    valid[:, 4] = is_mem & (line1 != line0)     # unaligned straddle
    kind[:, 4] = K_MEM
    line[:, 4] = line1
    is_write[:, 4] = is_store
    # slot 5: pure scalar bookkeeping.
    valid[:, 5] = op == isa.SCALAR
    kind[:, 5] = K_SCALAR

    # Attach the instruction base cost to its first valid event.
    cost = np.zeros((T, S), np.int32)
    any_valid = valid.any(axis=1)
    first = np.argmax(valid, axis=1)
    rows = np.nonzero(any_valid)[0]
    cost[rows, first[rows]] = base_cost[rows]

    flat = valid.reshape(-1)
    ev = EventStream(
        kind=kind.reshape(-1)[flat],
        reg=reg.reshape(-1)[flat],
        line=line.reshape(-1)[flat],
        is_write=is_write.reshape(-1)[flat],
        needs_read=needs_read.reshape(-1)[flat],
        no_fetch_ok=no_fetch.reshape(-1)[flat],
        cost=cost.reshape(-1)[flat],
        next_use=np.zeros(int(flat.sum()), np.int32),
        lock_a=lock_a.reshape(-1)[flat],
        lock_b=lock_b.reshape(-1)[flat],
        spill_line0=(program.memory.nbytes + isa.VLEN_BYTES - 1)
        // isa.VLEN_BYTES + 4,
        num_instructions=T,
    )
    ev.next_use = _next_use(ev.kind, ev.reg)
    return ev


def _next_use(kind: np.ndarray, reg: np.ndarray) -> np.ndarray:
    """Belady next-use indices for REG events (vectorised per register)."""
    E = kind.shape[0]
    nxt = np.full(E, NO_NEXT_USE, np.int32)
    reg_idx = np.nonzero(kind == K_REG)[0]
    regs_here = reg[reg_idx]
    for r in np.unique(regs_here):
        idx = reg_idx[regs_here == r]
        if idx.size > 1:
            nxt[idx[:-1]] = idx[1:]
    return nxt
