"""Instruction trace -> fixed-width per-instruction event matrices.

The Register Dispersion hardware checks the (up to three) vector operands of
an instruction *serially* in the ID stage (paper §3.2.1), then accesses the
data cache in EX for vector loads/stores.  Earlier versions of this engine
flattened those accesses into one event stream of length E ~ 2-3x the
instruction count and scanned it one event at a time.  The fused engine
instead keeps the *instruction* as the scan unit: each instruction owns

    REG slots  0..2:  [vs1?] [vs2?] [vd?]       (hardware tag-check order)
    MEM slots  0..1:  [line0?] [line1?]          (unaligned straddle in 1)

as masked lanes of fixed-width ``(T, 3)`` / ``(T, 2)`` matrices, so one
``lax.scan`` step retires one whole instruction with unrolled lane logic —
cutting the scan length ~2-3x and removing all per-event kind dispatch.

Event ordering (and therefore every counter) is identical to the flat
engine: timestamps are drawn from the *uncompacted* slot grid (vs1=0, vs2=1,
vd=2, mem0=3, mem1=4, scalar=5 within each instruction), a monotone map of
the old flat event index, so all relative-order decisions (L1 LRU ages,
cVRF LRU/FIFO/OPT metrics) are unchanged.

``v0`` (the RVV mask register) is pinned in a dedicated register and never
generates cVRF events (paper §3.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa
from repro.core.trace import Program

# Slots per instruction in the uncompacted timestamp grid
# (vs1, vs2, vd, mem0, mem1, scalar).
NUM_SLOTS = 6

NO_NEXT_USE = np.int32(2**31 - 8)


@dataclasses.dataclass
class EventStream:
    """Per-instruction event matrices (T = number of instructions).

    REG slot order is the hardware's serial tag-check order: vs1, vs2, vd.
    """

    reg_valid: np.ndarray    # (T, 3) bool  REG slot carries a cVRF access
    reg: np.ndarray          # (T, 3) int8  architectural register id
    vd_writes: np.ndarray    # (T,)  bool   vd slot is a write
    vd_reads: np.ndarray     # (T,)  bool   vd slot must fetch (vmacc family)
    vd_no_fetch: np.ndarray  # (T,)  bool   full overwrite, fetch skippable
    lock_vs1: np.ndarray     # (T,)  int8   tag locked during vs2/vd checks
    lock_vs2: np.ndarray     # (T,)  int8   tag locked during vd check
    mem_valid: np.ndarray    # (T, 2) bool  data-cache access lanes
    mem_line: np.ndarray     # (T, 2) int32 cacheline index (-1 if invalid)
    mem_write: np.ndarray    # (T, 2) bool
    cost: np.ndarray         # (T,)  int32  base cycles of the instruction
    next_use: np.ndarray     # (T, 3) int32 Belady next-use grid index
    events_per_row: np.ndarray  # (T,) int8 flat-engine event count per instr
    spill_line0: int         # first cacheline of the reserved spill region
    num_instructions: int
    repeats: list            # periodicity metadata (see trace.Program)

    @property
    def num_events(self) -> int:
        """Events the flat (per-event) engine would have scanned."""
        return int(self.events_per_row.sum())


def _reg_valid(tbl, op, vd, vs1, vs2) -> np.ndarray:
    """(T, 3) REG-slot validity mask: which vs1/vs2/vd fields name live
    cVRF accesses (``v0`` is pinned and never generates one).  Shared by
    :func:`expand` and :func:`next_use_grid` so the fused engine and the
    interpreter's Belady pre-pass always see the same access set."""
    mask_reg = isa.MASK_REG
    reg_valid = np.zeros((op.shape[0], 3), np.bool_)
    # slot 0/1: vs1 / vs2 reads.
    reg_valid[:, 0] = tbl["reads_vs1"][op] & (vs1 >= 0) & (vs1 != mask_reg)
    reg_valid[:, 1] = tbl["reads_vs2"][op] & (vs2 >= 0) & (vs2 != mask_reg)
    # slot 2: vd access (read and/or write).
    reg_valid[:, 2] = ((tbl["reads_vd"][op] | tbl["writes_vd"][op])
                       & (vd >= 0) & (vd != mask_reg))
    return reg_valid


def expand(program: Program, rows: np.ndarray | None = None) -> EventStream:
    """Vectorised numpy expansion of a trace into per-instruction matrices.

    ``rows``: optional sorted row index array — expand only those
    instructions (used by ``core.folding`` to expand a folded trace without
    materialising the full one).
    """
    tbl = isa.op_table()
    op, vd, vs1, vs2 = program.op, program.vd, program.vs1, program.vs2
    addr, cost_override = program.addr, program.cost_override
    if rows is not None:
        op, vd, vs1, vs2 = op[rows], vd[rows], vs1[rows], vs2[rows]
        addr, cost_override = addr[rows], cost_override[rows]
    T = op.shape[0]

    r_vd = tbl["reads_vd"][op]
    w_vd = tbl["writes_vd"][op]
    full_ow = tbl["full_overwrite"][op]
    is_load = tbl["is_load"][op]
    is_store = tbl["is_store"][op]
    cost = np.where(cost_override >= 0, cost_override,
                    tbl["cost"][op]).astype(np.int32)

    reg_valid = _reg_valid(tbl, op, vd, vs1, vs2)
    reg = np.zeros((T, 3), np.int8)
    reg[:, 0], reg[:, 1], reg[:, 2] = vs1, vs2, vd
    # Serial tag check (paper 3.2.1): vs2's miss handling must not evict the
    # already-resolved vs1; vd's must not evict vs1 or vs2.
    lock_vs1 = np.where(reg_valid[:, 0], vs1, -1).astype(np.int8)
    lock_vs2 = np.where(reg_valid[:, 1], vs2, -1).astype(np.int8)

    # MEM lanes: data-cache lines touched by vector loads/stores.
    is_mem = is_load | is_store
    nbytes = np.where((op == isa.VBCAST) | (op == isa.VSES), 4,
                      isa.VLEN_BYTES)
    line0 = addr >> 5
    line1 = (addr + nbytes - 1) >> 5
    mem_valid = np.zeros((T, 2), np.bool_)
    mem_line = np.full((T, 2), -1, np.int32)
    mem_valid[:, 0] = is_mem
    mem_line[:, 0] = np.where(is_mem, line0, -1)
    mem_valid[:, 1] = is_mem & (line1 != line0)     # unaligned straddle
    mem_line[:, 1] = np.where(mem_valid[:, 1], line1, -1)
    mem_write = mem_valid & is_store[:, None]

    events = (reg_valid.sum(1) + mem_valid.sum(1)
              + (op == isa.SCALAR)).astype(np.int8)
    ev = EventStream(
        reg_valid=reg_valid,
        reg=reg.astype(np.int8),
        vd_writes=(w_vd & reg_valid[:, 2]),
        vd_reads=(r_vd & reg_valid[:, 2]),
        vd_no_fetch=(full_ow & w_vd & ~r_vd & reg_valid[:, 2]),
        lock_vs1=lock_vs1,
        lock_vs2=lock_vs2,
        mem_valid=mem_valid,
        mem_line=mem_line,
        mem_write=mem_write,
        cost=cost,
        next_use=_next_use(reg, reg_valid),
        events_per_row=events,
        spill_line0=(program.memory.nbytes + isa.VLEN_BYTES - 1)
        // isa.VLEN_BYTES + 4,
        num_instructions=T,
        repeats=list(program.repeats) if rows is None else [],
    )
    return ev


def next_use_grid(program: Program) -> np.ndarray:
    """(T, 3) Belady next-use indices for a program's vs1/vs2/vd REG slots.

    The shared index space (row-major over the (T, 3) slot grid) is what the
    fused engine feeds OPT; the numpy interpreter uses this helper so its
    OPT victim choices compare the exact same farthest-next-use metric —
    the precondition for OPT rows of the differential conformance matrix.
    """
    op, vd, vs1, vs2 = program.op, program.vd, program.vs1, program.vs2
    reg_valid = _reg_valid(isa.op_table(), op, vd, vs1, vs2)
    reg = np.stack([vs1, vs2, vd], axis=1).astype(np.int8)
    return _next_use(reg, reg_valid)


def _next_use(reg: np.ndarray, reg_valid: np.ndarray) -> np.ndarray:
    """Belady next-use grid indices for REG slots, one stable-argsort pass.

    Index space is the row-major (T, 3) REG-slot grid — a monotone map of
    event order, which is all OPT's farthest-next-use comparison needs.
    """
    T = reg.shape[0]
    flat_valid = reg_valid.ravel()
    idx = np.flatnonzero(flat_valid)
    regs_here = reg.ravel()[idx]
    # Stable sort groups by register while keeping ascending event order
    # inside each group, so each entry's successor is its next use.
    order = np.argsort(regs_here, kind="stable")
    si = idx[order]
    sr = regs_here[order]
    nxt = np.full(T * 3, NO_NEXT_USE, np.int32)
    if si.size > 1:
        same = sr[:-1] == sr[1:]
        nxt[si[:-1][same]] = si[1:][same].astype(np.int32)
    return nxt.reshape(T, 3)


def _next_use_naive(reg: np.ndarray, reg_valid: np.ndarray) -> np.ndarray:
    """Reference implementation of :func:`_next_use` (per-register loop)."""
    T = reg.shape[0]
    nxt = np.full(T * 3, NO_NEXT_USE, np.int32)
    idx = np.flatnonzero(reg_valid.ravel())
    regs_here = reg.ravel()[idx]
    for r in np.unique(regs_here):
        ri = idx[regs_here == r]
        if ri.size > 1:
            nxt[ri[:-1]] = ri[1:]
    return nxt.reshape(T, 3)
