"""Cycle-level cVRF / Register Dispersion simulator (JAX ``lax.scan``).

Models the paper's microarchitecture (§3, Table 1):

  * compact VRF of ``capacity`` physical 256-bit registers, fully associative,
    tag array checked serially per operand, FIFO (or alternative) replacement;
  * ``v0`` pinned outside the cVRF (its accesses never reach the tag array);
  * every architectural register has a reserved memory address; spills/fills
    are 32-byte transfers through the modelled L1D (16 KB, 2-way, 32 B lines,
    1-cycle hit) backed by a 5-cycle main memory;
  * vector loads/stores share the same L1 port (integrated VPU, Fig 1);
  * a full-size VRF baseline (``capacity >= 32``) in which every operand
    access hits and no fills ever occur (real hardware has no compulsory
    misses — registers simply exist).

The whole sweep of Fig 4 (capacities 3..16 x policies) is one ``vmap`` over
the per-config axis of :func:`simulate_sweep`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev_mod
from repro.core import isa, policies
from repro.core.events import K_MEM, K_REG, EventStream
from repro.core.trace import Program

# ---------------------------------------------------------------------------
# Static machine parameters (Table 1).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MachineParams:
    l1_sets: int = 256            # 16 KB / 32 B lines / 2 ways
    l1_ways: int = 2
    l1_hit_cycles: int = 0        # data-path hits overlap the vector pipe
    uop_hit_cycles: int = 1       # spill/fill micro-ops serialize in ID
    mem_latency: int = 5          # main memory @200 MHz (Table 1: 1-5 cycles)

    def tree_flatten(self):  # convenience for static hashing in jit
        return dataclasses.astuple(self)


DEFAULT_MACHINE = MachineParams()

COUNTER_NAMES = (
    "cycles", "stall_cycles", "vrf_hits", "vrf_misses", "spills", "fills",
    "l1_hits", "l1_misses", "reg_reads", "reg_writes", "mem_reads",
    "mem_writes",
)


@dataclasses.dataclass
class SweepConfig:
    """Per-configuration sweep axes (arrays of equal length C)."""

    capacity: np.ndarray        # physical registers in the cVRF
    policy: np.ndarray          # policies.FIFO / LRU / LFU / OPT
    alloc_no_fetch: np.ndarray  # beyond-paper: skip fetch on full overwrite

    @staticmethod
    def make(capacities, policy=policies.FIFO, alloc_no_fetch=False):
        caps = np.asarray(capacities, np.int32)
        pol = np.broadcast_to(np.asarray(policy, np.int32), caps.shape).copy()
        anf = np.broadcast_to(np.asarray(alloc_no_fetch, bool),
                              caps.shape).copy()
        return SweepConfig(caps, pol, anf)


# ---------------------------------------------------------------------------
# L1 data cache model.
# ---------------------------------------------------------------------------


class L1State(dict):
    pass


def _l1_init(p: MachineParams):
    return dict(
        tags=jnp.full((p.l1_sets, p.l1_ways), -1, jnp.int32),
        age=jnp.zeros((p.l1_sets, p.l1_ways), jnp.int32),
        dirty=jnp.zeros((p.l1_sets, p.l1_ways), bool),
    )


def _l1_access(l1, line, is_write, now, p: MachineParams,
               hit_cost: int | None = None):
    """Returns (l1', cycles, hit). One cacheline access, LRU within the set,
    write-allocate + write-back.  ``hit_cost`` overrides the hit cycles
    (0 for pipelined data accesses, 1 for dispersion spill/fill uops)."""
    set_idx = (line % p.l1_sets).astype(jnp.int32)
    row_tags = l1["tags"][set_idx]
    row_age = l1["age"][set_idx]
    row_dirty = l1["dirty"][set_idx]
    eq = row_tags == line
    hit = eq.any()
    way = jnp.where(hit, jnp.argmax(eq), jnp.argmin(row_age))
    writeback = ~hit & (row_tags[way] >= 0) & row_dirty[way]
    hc = p.l1_hit_cycles if hit_cost is None else hit_cost
    cycles = jnp.where(
        hit, hc,
        hc + p.mem_latency
        + jnp.where(writeback, p.mem_latency, 0)).astype(jnp.int32)
    new_dirty = jnp.where(hit, row_dirty[way] | is_write, is_write)
    l1_new = dict(
        tags=l1["tags"].at[set_idx, way].set(line),
        age=l1["age"].at[set_idx, way].set(now),
        dirty=l1["dirty"].at[set_idx, way].set(new_dirty),
    )
    return l1_new, cycles, hit


def _where_tree(cond, a, b):
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


# ---------------------------------------------------------------------------
# Scan body.
# ---------------------------------------------------------------------------


def _make_step(p: MachineParams, spill_line0: int, n_slots: int):
    spill_line0 = jnp.int32(spill_line0)

    def step(carry, ev):
        cache, l1, seq, now, ctr, cfg = carry
        capacity, policy, alloc_no_fetch = cfg
        kind, reg, line, is_write, needs_read, no_fetch_ok, cost, nxt, lock_a, lock_b = ev
        is_reg = kind == K_REG
        is_mem = kind == K_MEM
        full_vrf = capacity >= isa.NUM_ARCH_VREGS
        valid_mask = jnp.arange(n_slots) < capacity

        # ------------------------------------------------- cVRF tag check --
        raw_hit, slot = policies.lookup(cache, reg, valid_mask)
        hit = raw_hit | full_vrf
        has_free, fslot = policies.free_slot(cache, valid_mask)
        victim = policies.select_victim(cache, policy, valid_mask,
                                lock_a, lock_b)
        tslot = jnp.where(has_free, fslot, victim)

        do_evict = is_reg & ~hit & ~has_free
        do_spill = do_evict & cache.dirty[victim]
        fetch = needs_read | ~(no_fetch_ok & alloc_no_fetch)
        do_fill = is_reg & ~hit & fetch

        # L1 traffic: spill (write evictee to its reserved address), then
        # fill (read the missing register), then the instruction's own data
        # access.  The three are chained select-updates on the same L1.
        ln_spill = spill_line0 + jnp.maximum(cache.tags[victim], 0)
        l1_a, c_a, h_a = _l1_access(l1, ln_spill, True, now, p,
                                    hit_cost=p.uop_hit_cycles)
        l1_1 = _where_tree(do_spill, l1_a, l1)
        c_spill = jnp.where(do_spill, c_a, 0)

        ln_fill = spill_line0 + jnp.maximum(reg, 0)
        l1_b, c_b, h_b = _l1_access(l1_1, ln_fill, False, now, p,
                                    hit_cost=p.uop_hit_cycles)
        l1_2 = _where_tree(do_fill, l1_b, l1_1)
        c_fill = jnp.where(do_fill, c_b, 0)

        l1_c, c_c, h_c = _l1_access(l1_2, line, is_write, now, p)
        l1_3 = _where_tree(is_mem, l1_c, l1_2)
        c_mem = jnp.where(is_mem, c_c, 0)

        # ------------------------------------------------ metadata update --
        upd_hit = policies.on_access(cache, slot, now=now, next_use=nxt,
                                     is_write=is_write, policy=policy)
        upd_miss = policies.on_install(cache, tslot, reg, now=now, seq=seq,
                                       next_use=nxt, is_write=is_write)
        new_cache = _where_tree(is_reg & raw_hit & ~full_vrf, upd_hit, cache)
        new_cache = _where_tree(is_reg & ~hit & ~full_vrf, upd_miss, new_cache)
        seq = seq + (is_reg & ~hit).astype(jnp.int32)

        # ------------------------------------------------------- counters --
        stall = c_spill + c_fill
        inc = dict(
            cycles=cost.astype(jnp.int32) + stall + c_mem,
            stall_cycles=stall,
            vrf_hits=(is_reg & hit).astype(jnp.int32),
            vrf_misses=(is_reg & ~hit).astype(jnp.int32),
            spills=do_spill.astype(jnp.int32),
            fills=do_fill.astype(jnp.int32),
            l1_hits=(do_spill & h_a).astype(jnp.int32)
            + (do_fill & h_b).astype(jnp.int32)
            + (is_mem & h_c).astype(jnp.int32),
            l1_misses=(do_spill & ~h_a).astype(jnp.int32)
            + (do_fill & ~h_b).astype(jnp.int32)
            + (is_mem & ~h_c).astype(jnp.int32),
            reg_reads=(is_reg & needs_read).astype(jnp.int32),
            reg_writes=(is_reg & is_write).astype(jnp.int32),
            mem_reads=(is_mem & ~is_write).astype(jnp.int32),
            mem_writes=(is_mem & is_write).astype(jnp.int32),
        )
        ctr = {k: ctr[k] + inc[k] for k in ctr}
        return (new_cache, l1_3, seq, now + 1, ctr, cfg), None

    return step


@functools.partial(jax.jit, static_argnums=(1, 2))
def _run_one(ev_arrays, p: MachineParams, spill_line0: int, cfg):
    n_slots = isa.NUM_ARCH_VREGS
    cache = policies.CacheState.init(n_slots)
    l1 = _l1_init(p)
    ctr = {k: jnp.int32(0) for k in COUNTER_NAMES}
    step = _make_step(p, spill_line0, n_slots)
    carry = (cache, l1, jnp.int32(0), jnp.int32(0), ctr, cfg)
    (cache, l1, _, _, ctr, _), _ = jax.lax.scan(step, carry, ev_arrays)
    return ctr


def _ev_arrays(ev: EventStream):
    return (
        jnp.asarray(ev.kind), jnp.asarray(ev.reg), jnp.asarray(ev.line.astype(np.int32)),
        jnp.asarray(ev.is_write), jnp.asarray(ev.needs_read),
        jnp.asarray(ev.no_fetch_ok), jnp.asarray(ev.cost),
        jnp.asarray(ev.next_use), jnp.asarray(ev.lock_a),
        jnp.asarray(ev.lock_b),
    )


def simulate_sweep(program_or_events, sweep: SweepConfig,
                   machine: MachineParams = DEFAULT_MACHINE,
                   max_events: int | None = None) -> dict[str, np.ndarray]:
    """Simulate one trace under C configurations (vmapped). Returns dict of
    (C,)-shaped counter arrays plus derived metrics."""
    ev = (program_or_events if isinstance(program_or_events, EventStream)
          else ev_mod.expand(program_or_events))
    arrays = _ev_arrays(ev)
    scale = 1.0
    if max_events is not None and ev.num_events > max_events:
        scale = ev.num_events / max_events
        arrays = tuple(a[:max_events] for a in arrays)
    cfg = (jnp.asarray(sweep.capacity), jnp.asarray(sweep.policy),
           jnp.asarray(sweep.alloc_no_fetch))
    fn = jax.vmap(lambda c: _run_one(arrays, machine, ev.spill_line0, c))
    out = {k: np.asarray(v) for k, v in fn(cfg).items()}
    out["event_scale"] = np.full(len(sweep.capacity), scale)
    total = out["vrf_hits"] + out["vrf_misses"]
    out["hit_rate"] = np.where(total > 0, out["vrf_hits"] / np.maximum(total, 1), 1.0)
    return out


def simulate_one(program, capacity, policy=policies.FIFO,
                 alloc_no_fetch=False,
                 machine: MachineParams = DEFAULT_MACHINE,
                 max_events: int | None = None) -> dict[str, float]:
    sweep = SweepConfig.make([capacity], policy, alloc_no_fetch)
    out = simulate_sweep(program, sweep, machine, max_events)
    return {k: v[0] for k, v in out.items()}


def full_vrf_baseline(program, machine: MachineParams = DEFAULT_MACHINE,
                      max_events: int | None = None) -> dict[str, float]:
    return simulate_one(program, isa.NUM_ARCH_VREGS, machine=machine,
                        max_events=max_events)


# ---------------------------------------------------------------------------
# Scalar-core baseline (the paper's Table 3 comparison point).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScalarCost:
    """Analytic cycle model of the -O2 scalar RISC-V version of a kernel.

    On a 3-stage in-order embedded core (Table 1):
      flop_ops:  FPU ops at ``flop_cycles`` each (low-cost FPUs are not
                 fully pipelined; fmadd ~2 cycles effective)
      int_ops:   1-cycle integer ALU ops (incl. branchy min/max selects)
      loads:     ``load_cycles`` each (L1 hit + average load-use hazard)
      stores:    1 cycle
      unique_lines: distinct cachelines -> compulsory-miss stalls
      loop_iters: per-iteration overhead (addr bump + cmp + taken branch;
                 embedded -O2 without aggressive unrolling)
    """

    flop_ops: int = 0
    int_ops: int = 0
    loads: int = 0
    stores: int = 0
    unique_lines: int = 0
    loop_iters: int = 0
    flop_cycles: float = 2.0
    load_cycles: float = 1.5
    overhead_per_iter: int = 3

    def cycles(self, machine: MachineParams = DEFAULT_MACHINE) -> int:
        return int(
            self.flop_ops * self.flop_cycles
            + self.int_ops
            + self.loads * self.load_cycles
            + self.stores
            + self.unique_lines * machine.mem_latency
            + self.loop_iters * self.overhead_per_iter)
