"""Cycle-level cVRF / Register Dispersion simulator (fused JAX ``lax.scan``).

Models the paper's microarchitecture (§3, Table 1):

  * compact VRF of ``capacity`` physical 256-bit registers, fully associative,
    tag array checked serially per operand, FIFO (or alternative) replacement;
  * ``v0`` pinned outside the cVRF (its accesses never reach the tag array);
  * every architectural register has a reserved memory address; spills/fills
    are 32-byte transfers through the modelled L1D (16 KB, 2-way, 32 B lines,
    1-cycle hit) backed by a 5-cycle main memory;
  * vector loads/stores share the same L1 port (integrated VPU, Fig 1);
  * a full-size VRF baseline (``capacity >= 32``) in which every operand
    access hits and no fills ever occur (real hardware has no compulsory
    misses — registers simply exist).

Engine architecture (fused instruction-level sweep engine), in one line
each — the full design narrative lives in ``docs/architecture.md``:

  * **One scan step retires one instruction** (``core.events`` packs the
    <=3 REG + <=2 MEM lanes into fixed-width matrices; counters are
    bit-identical to the old per-event engine).
  * **Batched (P, C, M) sweep grid**: :func:`simulate_grid` vmaps programs
    x configs x traced machine-latency points (:class:`MachineSweep`) into
    one dispatch, compiled once per power-of-two program-shape bucket.
  * **Exact periodic folding** (``core.folding``): warm-up + two measured
    periods per hot loop, algebraic extrapolation, with the A == B
    ``fold_exact`` certificate evaluated per (C, M) grid point — see
    ``docs/folding.md`` for the certificate semantics and the
    state-snapshot super-period detector.

The whole sweep of Fig 4 (capacities 3..16 x policies x every kernel) is
then one ``vmap(vmap(vmap(scan)))`` dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev_mod
from repro.core import folding, isa, policies
from repro.core.events import NO_NEXT_USE, EventStream
from repro.core.trace import Program

# ---------------------------------------------------------------------------
# Machine parameters (Table 1): static L1 geometry + traced latency axes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """One machine point.  ``l1_sets``/``l1_ways`` are static (they size the
    L1 state arrays); the three latency fields are *traced* by the engine, so
    machines sharing a geometry share one compiled executable."""

    l1_sets: int = 256            # 16 KB / 32 B lines / 2 ways
    l1_ways: int = 2
    l1_hit_cycles: int = 0        # data-path hits overlap the vector pipe
    uop_hit_cycles: int = 1       # spill/fill micro-ops serialize in ID
    mem_latency: int = 5          # main memory @200 MHz (Table 1: 1-5 cycles)

    def tree_flatten(self):  # convenience for static hashing in jit
        return dataclasses.astuple(self)


DEFAULT_MACHINE = MachineParams()


@dataclasses.dataclass
class MachineSweep:
    """Machine sweep axis: M traced latency points over one static L1
    geometry.  The latency arrays are vmapped through the fused step, so the
    whole machine grid shares one executable per program-shape bucket."""

    l1_hit_cycles: np.ndarray     # (M,) int32 data-path L1 hit cycles
    uop_hit_cycles: np.ndarray    # (M,) int32 spill/fill uop hit cycles
    mem_latency: np.ndarray       # (M,) int32 main-memory latency
    l1_sets: int = 256            # static: L1 state shape
    l1_ways: int = 2              # static: L1 state shape

    @staticmethod
    def make(mem_latency, l1_hit_cycles=0, uop_hit_cycles=1,
             l1_sets=256, l1_ways=2) -> "MachineSweep":
        mem = np.atleast_1d(np.asarray(mem_latency, np.int32))
        l1h = np.broadcast_to(np.asarray(l1_hit_cycles, np.int32),
                              mem.shape).copy()
        uop = np.broadcast_to(np.asarray(uop_hit_cycles, np.int32),
                              mem.shape).copy()
        return MachineSweep(l1h, uop, mem, l1_sets, l1_ways)

    @staticmethod
    def product(mem_latencies, l1_hit_cycles=(0,), uop_hit_cycles=(1,),
                l1_sets=256, l1_ways=2) -> "MachineSweep":
        """Cartesian latency grid as one machine axis (parameter order
        mirrors :meth:`make`)."""
        mem, l1h, uop = [], [], []
        for m in mem_latencies:
            for h in l1_hit_cycles:
                for u in uop_hit_cycles:
                    mem.append(m), l1h.append(h), uop.append(u)
        return MachineSweep(np.asarray(l1h, np.int32),
                            np.asarray(uop, np.int32),
                            np.asarray(mem, np.int32), l1_sets, l1_ways)

    @staticmethod
    def from_params(points) -> "MachineSweep":
        """Stack MachineParams points (which must share an L1 geometry)."""
        points = list(points)
        geo = {(p.l1_sets, p.l1_ways) for p in points}
        if len(geo) != 1:
            raise ValueError(
                f"machine points mix L1 geometries {sorted(geo)}; "
                "l1_sets/l1_ways are static (they size the L1 arrays) — "
                "sweep them in an outer loop")
        return MachineSweep(
            np.asarray([p.l1_hit_cycles for p in points], np.int32),
            np.asarray([p.uop_hit_cycles for p in points], np.int32),
            np.asarray([p.mem_latency for p in points], np.int32),
            points[0].l1_sets, points[0].l1_ways)

    def point(self, m: int) -> MachineParams:
        """The m-th machine point as a scalar MachineParams."""
        return MachineParams(self.l1_sets, self.l1_ways,
                             int(self.l1_hit_cycles[m]),
                             int(self.uop_hit_cycles[m]),
                             int(self.mem_latency[m]))

    def __len__(self):
        return len(self.mem_latency)


COUNTER_NAMES = (
    "cycles", "stall_cycles", "vrf_hits", "vrf_misses", "spills", "fills",
    "l1_hits", "l1_misses", "reg_reads", "reg_writes", "mem_reads",
    "mem_writes",
)


@dataclasses.dataclass
class SweepConfig:
    """Per-configuration sweep axes (arrays of equal length C)."""

    capacity: np.ndarray        # physical registers in the cVRF
    policy: np.ndarray          # policies.FIFO / LRU / LFU / OPT
    alloc_no_fetch: np.ndarray  # beyond-paper: skip fetch on full overwrite

    @staticmethod
    def make(capacities, policy=policies.FIFO, alloc_no_fetch=False):
        caps = np.asarray(capacities, np.int32)
        pol = np.broadcast_to(np.asarray(policy, np.int32), caps.shape).copy()
        anf = np.broadcast_to(np.asarray(alloc_no_fetch, bool),
                              caps.shape).copy()
        return SweepConfig(caps, pol, anf)

    @staticmethod
    def product(capacities, policies_, alloc_no_fetch=(False,)):
        """Cartesian grid capacities x policies x anf as one config axis."""
        caps, pols, anfs = [], [], []
        for c in capacities:
            for p in policies_:
                for a in alloc_no_fetch:
                    caps.append(c), pols.append(p), anfs.append(a)
        return SweepConfig(np.asarray(caps, np.int32),
                           np.asarray(pols, np.int32),
                           np.asarray(anfs, bool))

    def __len__(self):
        return len(self.capacity)


# ---------------------------------------------------------------------------
# L1 data cache model.
# ---------------------------------------------------------------------------


def _l1_init(l1_sets: int, l1_ways: int):
    # Packed (sets, ways, 2) int32: [:, :, 0] = line tag (-1 free),
    # [:, :, 1] = age << 1 | dirty.  Age dominates the packed word, so LRU
    # argmin over it matches argmin over the raw age; packing makes the
    # update a single 2-wide scatter per access.
    l1 = jnp.zeros((l1_sets, l1_ways, 2), jnp.int32)
    return l1.at[:, :, 0].set(-1)


def _l1_access(l1, line, is_write, now, active, l1_sets: int,
               hit_cost, mem_latency):
    """One cacheline access, LRU within the set, write-allocate + write-back.

    Returns ``(l1', cycles, hit)``; the state update is a masked scatter at
    the touched (set, way) entry, a no-op when ``active`` is False, and
    ``cycles`` is already gated by ``active``.  ``hit_cost`` (the L1 hit
    cycles of this access class: data path vs spill/fill uop) and
    ``mem_latency`` are traced int32 scalars — machine sweep axes — while
    ``l1_sets`` stays static because it indexes the state array.  Hit/miss
    state transitions do not depend on the latencies, only ``cycles`` does.
    """
    line = line.astype(jnp.int32)
    set_idx = line % l1_sets
    row = l1[set_idx]                              # (ways, 2)
    row_tags = row[:, 0]
    eq = row_tags == line
    hit = eq.any()
    way = jnp.where(hit, jnp.argmax(eq), jnp.argmin(row[:, 1]))
    old = row[way]
    old_dirty = old[1] & 1
    writeback = ~hit & (old[0] >= 0) & (old_dirty == 1)
    cycles = jnp.where(
        hit, hit_cost,
        hit_cost + mem_latency
        + jnp.where(writeback, mem_latency, 0)).astype(jnp.int32)
    w = jnp.int32(is_write)
    new = jnp.stack([line, (now << 1) | jnp.where(hit, old_dirty | w, w)])
    l1_new = l1.at[set_idx, way].set(jnp.where(active, new, old))
    return l1_new, jnp.where(active, cycles, 0), hit


# ---------------------------------------------------------------------------
# Fused per-instruction scan body.
# ---------------------------------------------------------------------------


# L1 access sites one instruction can touch, in engine order: (spill, fill)
# per REG slot 0..2, then the two MEM lanes.  The per-site missed-line
# vector is the per-core L1-miss stream the cluster engine's shared-L2 /
# memory-channel arbiter consumes (repro.cluster).
NUM_MISS_SITES = 8


def _make_body(l1_sets, slots_used, cfg, mach):
    """The per-instruction engine body, shared by the single-core step and
    the cluster engine's vmapped per-core step (:mod:`repro.cluster`).

    Returns ``body(state, xs, spill0, mem_base, now0) -> (state', inc,
    miss_lines)`` where ``state = (cache, l1, seq)``, ``inc`` is the (12,)
    counter increment vector (order = COUNTER_NAMES) and ``miss_lines`` is
    the (NUM_MISS_SITES,) int32 vector of cachelines this instruction
    missed in the L1 (-1 at sites that hit, were inactive, or are unused).
    ``mem_base`` offsets the instruction's own data lines (per-core address
    colouring in a cluster; 0 on the single-core path, where the per-core
    offset is instead folded into ``spill0`` for the spill region).
    """
    capacity, policy, anf = cfg
    l1_hit, uop_hit, mem_lat = mach
    full_vrf = capacity >= isa.NUM_ARCH_VREGS
    valid_mask = jnp.arange(isa.NUM_ARCH_VREGS) < capacity
    F = jnp.bool_(False)
    no_lock = jnp.int8(-1)
    neg1 = jnp.int32(-1)

    def body(state, xs, spill0, mem_base, now0):
        cache, l1, seq = state
        (rv, rg, vdw, vdr, vdnf, lk1, lk2, mv, ml, mw, cost, nxt,
         _wt, _wa, _wb) = xs
        i32 = lambda b: b.astype(jnp.int32)
        z = jnp.int32(0)
        stall = memc = hits = misses = spills = fills = z
        l1h = l1m = rr = rw = mr = mw_ = z
        miss_lines = [neg1] * NUM_MISS_SITES

        # REG lanes in the hardware's serial tag-check order.
        write_of = (F, F, vdw)
        read_of = (jnp.bool_(True), jnp.bool_(True), vdr)
        nofetch_of = (F, F, vdnf)
        locks = ((no_lock, no_lock), (lk1, no_lock), (lk1, lk2))
        for s in range(3):
            if not slots_used[s]:
                continue
            active = rv[s]
            now = now0 + s
            raw_hit, slot = policies.lookup(cache, rg[s], valid_mask)
            raw_hit = raw_hit & active
            has_free, fslot = policies.free_slot(cache, valid_mask)
            la, lb = locks[s]
            victim = policies.select_victim(cache, policy, valid_mask,
                                            la, lb)
            tslot = jnp.where(has_free, fslot, victim)
            vrow = cache.meta[victim]
            miss = active & ~raw_hit & ~full_vrf
            do_spill = miss & ~has_free & (vrow[policies.DIRTY] == 1)
            wr, rd = write_of[s], read_of[s]
            fetch = rd | ~(nofetch_of[s] & anf)
            do_fill = miss & fetch
            # Spill the evictee to its reserved line, then fill the missing
            # register — both 1-cycle uops through the L1.
            spill_line = spill0 + jnp.maximum(vrow[policies.TAG], 0)
            fill_line = spill0 + jnp.maximum(rg[s].astype(jnp.int32), 0)
            l1, c_sp, h_sp = _l1_access(
                l1, spill_line, True, now,
                do_spill, l1_sets, uop_hit, mem_lat)
            l1, c_fl, h_fl = _l1_access(
                l1, fill_line, False,
                now, do_fill, l1_sets, uop_hit, mem_lat)
            cache = policies.apply_access(
                cache, active=active & ~full_vrf, raw_hit=raw_hit,
                hit_slot=slot, install_slot=tslot, tag=rg[s], now=now,
                seq=seq, next_use=nxt[s], is_write=wr)
            seq = seq + i32(miss)
            stall += c_sp + c_fl
            hits += i32(raw_hit | (active & full_vrf))
            misses += i32(miss)
            spills += i32(do_spill)
            fills += i32(do_fill)
            l1h += i32(do_spill & h_sp) + i32(do_fill & h_fl)
            l1m += i32(do_spill & ~h_sp) + i32(do_fill & ~h_fl)
            rr += i32(active & rd)
            rw += i32(active & wr)
            miss_lines[2 * s] = jnp.where(do_spill & ~h_sp,
                                          spill_line.astype(jnp.int32), neg1)
            miss_lines[2 * s + 1] = jnp.where(do_fill & ~h_fl,
                                              fill_line.astype(jnp.int32),
                                              neg1)

        # MEM lanes: the instruction's own data accesses.
        for m in range(2):
            if not slots_used[3 + m]:
                continue
            active = mv[m]
            line = ml[m] + mem_base
            l1, c_m, h_m = _l1_access(l1, line, mw[m], now0 + 3 + m,
                                      active, l1_sets, l1_hit, mem_lat)
            memc += c_m
            l1h += i32(active & h_m)
            l1m += i32(active & ~h_m)
            mr += i32(active & ~mw[m])
            mw_ += i32(active & mw[m])
            miss_lines[6 + m] = jnp.where(active & ~h_m,
                                          line.astype(jnp.int32), neg1)

        # One (12,)-vector FMA per counter set (order = COUNTER_NAMES).
        inc = jnp.stack([
            cost + stall + memc, stall, hits, misses, spills, fills,
            l1h, l1m, rr, rw, mr, mw_,
        ])
        return (cache, l1, seq), inc, jnp.stack(miss_lines)

    return body


def _make_step(l1_sets, slots_used, track_ab, spill0, cfg, mach):
    body = _make_body(l1_sets, slots_used, cfg, mach)
    spill0 = spill0.astype(jnp.int32)
    zero_base = jnp.int32(0)

    def step(carry, xs):
        cache, l1, seq, now0, ctr, ctrA, ctrB = carry
        wt, wa, wb = xs[-3:]
        (cache, l1, seq), inc, _ = body(
            (cache, l1, seq), xs, spill0, zero_base, now0)
        ctr = ctr + inc * wt
        if track_ab:
            ctrA = ctrA + inc * wa
            ctrB = ctrB + inc * wb
        return (cache, l1, seq, now0 + ev_mod.NUM_SLOTS, ctr, ctrA, ctrB), None

    return step


# Number of times the grid engine has been traced (== XLA compiles): the
# body below only executes under jax tracing, so the counter increments
# exactly once per new (static signature, shape bucket) cache entry.
_COMPILES = 0


def compile_count() -> int:
    """Grid-engine compiles so far (one per program-shape bucket)."""
    return _COMPILES


# Grid-engine dispatches (one `_run_grid` call each; a dispatch reuses a
# compiled executable unless its static/shape signature is new).
_DISPATCHES = 0


def dispatch_count() -> int:
    """Grid-engine XLA dispatches so far (compiled-or-cached alike)."""
    return _DISPATCHES


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3),
                   donate_argnums=(4, 5))
def _run_grid(l1_sets, l1_ways, slots_used, track_ab, arrays, spill0s,
              cfg, mach):
    """(P, T) trace grid x (C,) configs x (M,) machines -> (P, C, M, 12).

    The jit cache keyed on the (static) L1-geometry/lane signature and the
    (padded) array shapes is the compiled-executable level of the benchmark
    cache: any suite whose grid pads to the same bucket reuses the build —
    including every machine-latency point, since ``mach`` is traced.  The
    trace grid and spill bases are donated (they are rebuilt from the host
    copies each call), trimming peak memory on accelerator backends.
    """
    global _COMPILES
    _COMPILES += 1

    def one_program(arr, sp0):
        def one_cfg(c):
            def one_machine(m):
                step = _make_step(l1_sets, slots_used, track_ab, sp0, c, m)
                z = jnp.zeros(len(COUNTER_NAMES), jnp.int32)
                carry = (policies.CacheState.init(isa.NUM_ARCH_VREGS),
                         _l1_init(l1_sets, l1_ways), jnp.int32(0),
                         jnp.int32(0), z, z, z)
                (_, _, _, _, ctr, ctrA, ctrB), _ = jax.lax.scan(
                    step, carry, arr)
                return ctr, ctrA, ctrB
            return jax.vmap(one_machine)(mach)
        return jax.vmap(one_cfg)(cfg)

    return jax.vmap(one_program)(arrays, spill0s)


# ---------------------------------------------------------------------------
# Trace preparation: expansion + optional periodic folding / truncation.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PreparedTrace:
    """An expanded (and possibly folded / truncated) trace, ready to grid."""

    ev: EventStream
    weight: np.ndarray        # (T',) int32 extrapolation weights (ones if
    wa: np.ndarray            # unfolded); wa/wb pick out the two measured
    wb: np.ndarray            # periods whose equality certifies exactness
    num_folds: int
    event_scale: float        # >1 when prefix-truncated via max_events
    spill_line0: int
    certifiable: bool = True  # False: post-fold rows reuse dropped lines,
    #   so A == B cannot certify exactness (folding.FoldPlan.certifiable)

    @property
    def num_rows(self) -> int:
        return self.ev.num_instructions


def _slice_prep(prep: PreparedTrace, t: int) -> PreparedTrace:
    ev = prep.ev
    sliced = EventStream(
        reg_valid=ev.reg_valid[:t], reg=ev.reg[:t],
        vd_writes=ev.vd_writes[:t], vd_reads=ev.vd_reads[:t],
        vd_no_fetch=ev.vd_no_fetch[:t], lock_vs1=ev.lock_vs1[:t],
        lock_vs2=ev.lock_vs2[:t], mem_valid=ev.mem_valid[:t],
        mem_line=ev.mem_line[:t], mem_write=ev.mem_write[:t],
        cost=ev.cost[:t], next_use=ev.next_use[:t],
        events_per_row=ev.events_per_row[:t],
        spill_line0=ev.spill_line0, num_instructions=t, repeats=[],
    )
    return dataclasses.replace(prep, ev=sliced, weight=prep.weight[:t],
                               wa=prep.wa[:t], wb=prep.wb[:t])


def prepare(program_or_events, fold: bool = False,
            max_events: int | None = None,
            warm_lines: int | None = None,
            machine=None) -> PreparedTrace:
    """Expand a trace once; optionally fold its periodic loops (exact for
    steady-state traces) or truncate it to ``max_events`` flat events at an
    instruction boundary (approximate, the legacy prefix mode).

    The two modes are mutually exclusive: truncating a folded trace would
    drop the extrapolation-weighted measured periods and corrupt both the
    counters and the exactness certificate, so ``max_events`` forces
    ``fold`` off.

    ``machine`` (a :class:`MachineParams` or :class:`MachineSweep`) sizes
    the fold warm-up to the static L1 geometry the trace will be swept on
    (2x its line count, see ``folding.warm_lines_for``); traced latency
    axes never affect preparation.  An explicit ``warm_lines`` wins.
    """
    if isinstance(program_or_events, PreparedTrace):
        return program_or_events
    if warm_lines is None:
        geo = machine if machine is not None else DEFAULT_MACHINE
        warm_lines = folding.warm_lines_for(geo.l1_sets, geo.l1_ways)
    if max_events is not None:
        fold = False
    plan = None
    if isinstance(program_or_events, EventStream):
        if fold:
            # Fold planning needs the Program (warm-up sizing reads the raw
            # address stream); refusing beats silently scanning in full.
            raise ValueError(
                "fold=True requires a Program (or a PreparedTrace from "
                "prepare(program, fold=True)), not a pre-expanded "
                "EventStream")
        ev = program_or_events
    else:
        if fold:
            plan = folding.plan(program_or_events, warm_lines=warm_lines)
        ev = ev_mod.expand(
            program_or_events, rows=plan.rows if plan else None)
    T = ev.num_instructions
    if plan is not None:
        prep = PreparedTrace(ev, plan.weight, plan.wa, plan.wb,
                             plan.num_folds, 1.0, ev.spill_line0,
                             certifiable=plan.certifiable)
    else:
        ones = np.ones(T, np.int32)
        zeros = np.zeros(T, np.int32)
        prep = PreparedTrace(ev, ones, zeros, zeros, 0, 1.0, ev.spill_line0)
    total = ev.num_events
    if max_events is not None and total > max_events:
        cum = np.cumsum(ev.events_per_row)
        t = max(int(np.searchsorted(cum, max_events, side="right")), 1)
        prep = _slice_prep(prep, t)
        prep.event_scale = total / float(cum[t - 1])
    return prep


def _bucket(t: int) -> int:
    """Round the grid length up to a power of two so differently folded
    suites reuse one compiled executable per bucket."""
    b = 1024
    while b < t:
        b *= 2
    return b


def _stack(preps: list[PreparedTrace], pad_to: int | None = None):
    t_pad = pad_to or _bucket(max(p.num_rows for p in preps))

    def pad(get, fill, dtype=None):
        outs = []
        for pr in preps:
            a = get(pr)
            if a.ndim == 1:
                full = np.full(t_pad, fill, a.dtype if dtype is None
                               else dtype)
            else:
                full = np.full((t_pad, a.shape[1]), fill,
                               a.dtype if dtype is None else dtype)
            full[: len(a)] = a
            outs.append(full)
        return np.stack(outs)

    arrays = (
        pad(lambda p: p.ev.reg_valid, False),
        pad(lambda p: p.ev.reg, 0),
        pad(lambda p: p.ev.vd_writes, False),
        pad(lambda p: p.ev.vd_reads, False),
        pad(lambda p: p.ev.vd_no_fetch, False),
        pad(lambda p: p.ev.lock_vs1, -1),
        pad(lambda p: p.ev.lock_vs2, -1),
        pad(lambda p: p.ev.mem_valid, False),
        pad(lambda p: p.ev.mem_line, -1),
        pad(lambda p: p.ev.mem_write, False),
        pad(lambda p: p.ev.cost, 0),
        pad(lambda p: p.ev.next_use, NO_NEXT_USE),
        pad(lambda p: p.weight, 0),
        pad(lambda p: p.wa, 0),
        pad(lambda p: p.wb, 0),
    )
    spill0s = np.asarray([p.spill_line0 for p in preps], np.int32)
    slots_used = tuple(
        bool(arrays[0][:, :, s].any()) for s in range(3)
    ) + tuple(bool(arrays[7][:, :, m].any()) for m in range(2))
    return arrays, spill0s, slots_used


def _dispatch_grid(machine: MachineSweep, slots_used, track_ab, arrays,
                   spill0s, cfg, mach):
    """One `_run_grid` call with donation noise suppressed: the counter
    outputs are far smaller than the donated trace grid, so XLA may decline
    the alias and warn — harmless, the donation is an upper bound."""
    global _DISPATCHES
    _DISPATCHES += 1
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _run_grid(machine.l1_sets, machine.l1_ways, slots_used,
                         track_ab, tuple(jnp.asarray(a) for a in arrays),
                         jnp.asarray(spill0s), cfg, mach)


def simulate_grid(preps: list, sweep: SweepConfig,
                  machine=DEFAULT_MACHINE,
                  batch_programs: bool = False) -> dict[str, np.ndarray]:
    """Simulate P prepared traces under C configurations in one sweep call.

    ``machine`` is either one :class:`MachineParams` point (returns (P, C)
    counter arrays, the classic grid) or a :class:`MachineSweep` of M traced
    latency points (returns (P, C, M) arrays — the whole machine grid in the
    same dispatch, one compile per program-shape bucket).  Alongside the raw
    counters the dict carries ``hit_rate`` and, for folded traces,
    ``fold_exact`` (measured periods A == B => the algebraic extrapolation
    is exact, certified independently at every (C, M) grid point).

    ``batch_programs=True`` pads every trace to one bucket and vmaps the
    program axis into a single XLA dispatch — the right shape for
    accelerator backends.  The default dispatches per program (configs
    stay vmapped): on CPU the batched lanes execute serially anyway, so
    per-program dispatches avoid padding every trace to the longest one
    while the power-of-two shape buckets keep executable reuse across
    programs and suites.
    """
    preps = [prepare(p) if not isinstance(p, PreparedTrace) else p
             for p in preps]
    squeeze_m = not isinstance(machine, MachineSweep)
    machines = MachineSweep.from_params([machine]) if squeeze_m else machine
    cfg = (jnp.asarray(sweep.capacity), jnp.asarray(sweep.policy),
           jnp.asarray(sweep.alloc_no_fetch))
    mach = (jnp.asarray(machines.l1_hit_cycles),
            jnp.asarray(machines.uop_hit_cycles),
            jnp.asarray(machines.mem_latency))
    if batch_programs:
        arrays, spill0s, slots_used = _stack(preps)
        track_ab = any(p.num_folds for p in preps)
        ctr, ctrA, ctrB = _dispatch_grid(machines, slots_used, track_ab,
                                         arrays, spill0s, cfg, mach)
        ctr, ctrA, ctrB = (np.asarray(x) for x in (ctr, ctrA, ctrB))
    else:
        outs = []
        for prep in preps:
            arrays, spill0s, slots_used = _stack([prep])
            outs.append(_dispatch_grid(machines, slots_used,
                                       prep.num_folds > 0, arrays, spill0s,
                                       cfg, mach))
        ctr = np.concatenate([np.asarray(o[0]) for o in outs])
        ctrA = np.concatenate([np.asarray(o[1]) for o in outs])
        ctrB = np.concatenate([np.asarray(o[2]) for o in outs])
    if squeeze_m:
        ctr, ctrA, ctrB = ctr[:, :, 0], ctrA[:, :, 0], ctrB[:, :, 0]
    out = {k: ctr[..., i] for i, k in enumerate(COUNTER_NAMES)}
    grid_shape = out["cycles"].shape              # (P, C) or (P, C, M)
    per_prog = (-1,) + (1,) * (len(grid_shape) - 1)
    if any(p.num_folds for p in preps):
        steady = (ctrA == ctrB).all(axis=-1)
        steady &= np.asarray(
            [p.certifiable for p in preps]).reshape(per_prog)
        unfolded = np.asarray([p.num_folds == 0 for p in preps])
        steady[unfolded] = True
        out["fold_exact"] = steady
    total = out["vrf_hits"] + out["vrf_misses"]
    with np.errstate(divide="ignore", invalid="ignore"):
        out["hit_rate"] = np.where(total > 0, out["vrf_hits"] / total, 1.0)
    out["event_scale"] = np.broadcast_to(
        np.asarray([p.event_scale for p in preps]).reshape(per_prog),
        grid_shape).copy()
    return out


def simulate_sweep(program_or_events, sweep: SweepConfig,
                   machine=DEFAULT_MACHINE,
                   max_events: int | None = None,
                   fold: bool = False) -> dict[str, np.ndarray]:
    """Deprecated: use :func:`repro.api.sweep_program` (one raw program) or
    a :class:`repro.api.Session` running a declarative ``Sweep`` (named
    kernels).  This shim delegates to ``repro.api`` and returns the same
    dict of (C,)-shaped — (C, M)-shaped under a :class:`MachineSweep` —
    counter arrays the old entry point produced."""
    warnings.warn(
        "simulator.simulate_sweep is deprecated; use repro.api.sweep_program"
        " (or Session.run with a declarative Sweep) instead",
        DeprecationWarning, stacklevel=2)
    from repro import api  # runtime import: api sits above the core layer
    return api.sweep_program(program_or_events, sweep, machine=machine,
                             fold=fold, max_events=max_events)


def simulate_one(program, capacity, policy=policies.FIFO,
                 alloc_no_fetch=False,
                 machine=DEFAULT_MACHINE,
                 max_events: int | None = None,
                 fold: bool = False) -> dict[str, float]:
    prep = prepare(program, fold=fold, max_events=max_events,
                   machine=machine)
    sweep = SweepConfig.make([capacity], policy, alloc_no_fetch)
    out = simulate_grid([prep], sweep, machine)
    return {k: v[0, 0] for k, v in out.items()}


def full_vrf_baseline(program, machine: MachineParams = DEFAULT_MACHINE,
                      max_events: int | None = None) -> dict[str, float]:
    return simulate_one(program, isa.NUM_ARCH_VREGS, machine=machine,
                        max_events=max_events)


# ---------------------------------------------------------------------------
# Scalar-core baseline (the paper's Table 3 comparison point).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScalarCost:
    """Analytic cycle model of the -O2 scalar RISC-V version of a kernel.

    On a 3-stage in-order embedded core (Table 1):
      flop_ops:  FPU ops at ``flop_cycles`` each (low-cost FPUs are not
                 fully pipelined; fmadd ~2 cycles effective)
      int_ops:   1-cycle integer ALU ops (incl. branchy min/max selects)
      loads:     ``load_cycles`` each (L1 hit + average load-use hazard)
      stores:    1 cycle
      unique_lines: distinct cachelines -> compulsory-miss stalls
      loop_iters: per-iteration overhead (addr bump + cmp + taken branch;
                 embedded -O2 without aggressive unrolling)
    """

    flop_ops: int = 0
    int_ops: int = 0
    loads: int = 0
    stores: int = 0
    unique_lines: int = 0
    loop_iters: int = 0
    flop_cycles: float = 2.0
    load_cycles: float = 1.5
    overhead_per_iter: int = 3

    def cycles(self, machine=DEFAULT_MACHINE):
        """Scalar-core cycles; with a :class:`MachineSweep` the result is an
        (M,) int64 array over the swept memory latencies."""
        base = (self.flop_ops * self.flop_cycles
                + self.int_ops
                + self.loads * self.load_cycles
                + self.stores
                + self.loop_iters * self.overhead_per_iter)
        mem = self.unique_lines * np.asarray(machine.mem_latency)
        total = base + mem
        if isinstance(machine, MachineSweep):
            return total.astype(np.int64)
        return int(total)
