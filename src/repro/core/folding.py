"""Exact periodic folding of repeat-generated instruction traces.

``Assembler.repeat`` records ``(start, block_len, count)`` metadata for every
expanded repeat block (``Program.repeats``).  Hot benchmark loops are
periodic, so instead of simulating millions of near-identical iterations (or
lossily truncating the trace, as the old ``MAX_EVENTS`` prefix did), we

  1. keep a *warm-up* prefix of each sufficiently long repeat block — enough
     iterations to stream ~2x the L1 capacity so the cache reaches its
     steady state,
  2. keep two further *measured* super-periods A and B, and
  3. drop the remaining iterations, giving every instruction of B an integer
     extrapolation ``weight`` so counters come out as
     ``total = head + warmup + A + (count - warmup - 1) * B``.

Folding is recursive (blocks nested inside a kept period fold again) and
multiplicative (a nested B weight multiplies the enclosing one).  The
simulator accumulates three counter sets — total (weighted), period A and
period B — and reports ``fold_exact`` when A == B, i.e. the trace really was
in steady state and the algebraic extrapolation is exact.

Machine axes: the fold plan depends only on the *address stream* and the
static L1 geometry (warm-up streams 2x its line count, see
:func:`warm_lines_for`) — never on the traced latency parameters, which
affect cycle arithmetic but no replacement decision.  The A == B
certificate is therefore evaluated independently at every (capacity,
policy, machine) grid point, so one fold plan extrapolates exactly across
a whole traced machine sweep.

A *super-period* groups ``unit`` consecutive iterations (8 by default when
the count allows) so that sub-cacheline strides (e.g. 4-byte broadcast
streams, 8 elements per 32-byte line) complete a whole line per measured
period and the per-period counter deltas are constant.

State-snapshot period detection (multi-iteration steady states)
---------------------------------------------------------------

Some kernels reach steady state only over a period *longer than one
iteration of any single emitted repeat*: jacobi2d's ping-pong buffers swap
source and destination every time step, so the trace is periodic with
period TWO steps, a loop the Assembler never emitted as one repeat block.
:func:`plan` therefore runs a detection pass over runs of adjacent
top-level repeat blocks: it finds the smallest k for which the instruction
stream is literally periodic with a k-block super-period, then certifies
the candidate by *state snapshots* — fingerprints of the address stream's
cache-relevant state (per-line last-touch offsets + the stale-line set) at
every candidate period boundary.  The first boundary from which all
fingerprints agree sizes the warm-up; a candidate whose fingerprints never
stabilise is rejected.  Accepted candidates are synthesised as ordinary
fold segments (``ping-pong => k = 2`` blocks per period) and folded by the
standard warm-up + A + B machinery.

Exact-outer planning (certifying folds the nested plan cannot)
--------------------------------------------------------------

The nested plan folds every sufficiently long loop, including loops inside
another fold's warm-up and measured periods.  That maximises compression
but leaves the simulated cache state *approximate* inside each kept outer
period, and drops iterations whose lines later rows reuse — both of which
forfeit the exactness certificate (``FoldPlan.certifiable``).  When that
happens, :func:`plan` re-plans in *exact-outer* mode: only the outermost
foldable block of each nest folds, and its warm-up and measured periods
are simulated in full (no nested folding), so A and B measure the true
per-period counters.  The certified exact-outer plan keeps more rows than
the nested one but replaces a full unfolded re-simulation; the nested plan
is kept whenever exact-outer cannot be certified either.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import Program

#: Fields that must match for two trace rows to be considered identical by
#: the super-period detector (everything the simulator reads).
_PERIODIC_FIELDS = ("op", "vd", "vs1", "vs2", "addr", "imm", "cost_override")


def warm_lines_for(l1_sets: int, l1_ways: int) -> int:
    """Warm-up stream length (cachelines) for an L1 geometry: 2x its line
    count reaches LRU steady state within every set before measurement."""
    return 2 * l1_sets * l1_ways


@dataclasses.dataclass
class FoldPlan:
    """Row selection + extrapolation weights for a folded trace."""

    rows: np.ndarray      # (T',) int64 kept instruction rows, ascending
    weight: np.ndarray    # (T',) int32 total-counter weight per row
    wa: np.ndarray        # (T',) int32 contribution to one measured period A
    wb: np.ndarray        # (T',) int32 contribution to one measured period B
    num_folds: int        # repeat blocks actually folded
    num_rows_full: int    # rows of the unfolded trace
    certifiable: bool = True   # False: kept rows after a folded block reuse
    #   the block's dropped lines, so the runtime A == B check cannot see
    #   the post-loop state divergence and must not certify exactness.
    num_super_periods: int = 0   # detected multi-block super-periods folded
    exact_outer: bool = False    # plan came from the exact-outer re-plan

    @property
    def kept_fraction(self) -> float:
        return len(self.rows) / max(self.num_rows_full, 1)


@dataclasses.dataclass
class _Node:
    s: int
    bl: int
    cnt: int
    children: list
    super_: bool = False     # synthesised multi-block super-period
    warm: int = 0            # snapshot-derived warm-up (super nodes only)

    @property
    def e(self) -> int:
        return self.s + self.bl * self.cnt


def _build_tree(nodes: list) -> list:
    """Nest _Node segments by containment (they are properly nested or
    disjoint by construction).  Children are rebuilt from scratch so the
    same nodes can be re-treed across planning passes."""
    nodes = sorted(nodes, key=lambda n: (n.s, -(n.bl * n.cnt)))
    roots, stack = [], []
    for nd in nodes:
        nd.children = []
    for nd in nodes:
        while stack and nd.s >= stack[-1].e:
            stack.pop()
        (stack[-1].children if stack else roots).append(nd)
        stack.append(nd)
    return roots


# ---------------------------------------------------------------------------
# State-snapshot super-period detection.
# ---------------------------------------------------------------------------


def _rows_periodic(program: Program, s: int, P: int, cnt: int) -> bool:
    """True when rows [s, s + cnt*P) are literally periodic with period P
    on every simulator-visible field."""
    if cnt < 2:
        return False
    for f in _PERIODIC_FIELDS:
        arr = getattr(program, f)
        if not np.array_equal(arr[s: s + (cnt - 1) * P],
                              arr[s + P: s + cnt * P]):
            return False
    return True


def _boundary_fingerprint(addr: np.ndarray, s: int, P: int, j: int,
                          seen_before: set):
    """Cache-state fingerprint at the end of period ``j`` of a candidate
    super-period: (line -> last-touch offset within the period) plus the
    set of *stale* lines (touched earlier, untouched this period).  Two
    boundaries with equal fingerprints present the same relative-recency
    state to an LRU-like cache — absolute ages differ, but every
    replacement decision the engine makes compares ages, not reads them.
    """
    a = addr[s + j * P: s + (j + 1) * P]
    idx = np.flatnonzero(a >= 0)
    lines = (a[idx] >> 5).astype(np.int64)
    # last occurrence per line: unique() on the reversed stream returns the
    # first (= originally last) index of each line.
    rev_lines = lines[::-1]
    u, first_rev = np.unique(rev_lines, return_index=True)
    last_off = idx[len(idx) - 1 - first_rev]
    touched = set(u.tolist())
    stale = frozenset(seen_before - touched)
    return (tuple(u.tolist()), tuple(last_off.tolist()), stale), touched


def _snapshot_warm(addr: np.ndarray, s: int, P: int, cnt: int) -> int | None:
    """Snapshot the address stream's state at every candidate period
    boundary and return the first warm-up count w >= 1 from which all
    remaining fingerprints agree (steady state reached), or None when the
    fingerprints never stabilise."""
    pre = addr[:s]
    seen = set(np.unique(pre[pre >= 0] >> 5).tolist())
    fps = []
    for j in range(cnt):
        fp, touched = _boundary_fingerprint(addr, s, P, j, seen)
        seen |= touched
        fps.append(fp)
    for w in range(1, cnt - 2):          # leave >= A + B after the warm-up
        if all(fp == fps[w] for fp in fps[w + 1:]):
            return w
    return None


def detect_super_periods(program: Program):
    """Detect multi-block steady-state periods over runs of adjacent
    top-level repeat blocks.

    Returns synthesised ``_Node`` segments (``super_=True``) whose period
    spans k >= 1 consecutive top-level blocks, with the snapshot-derived
    warm-up attached.  A ping-pong time loop (jacobi2d) detects k = 2; a
    plain unrolled loop of identical blocks detects k = 1.
    """
    base = [_Node(s, bl, cnt, []) for s, bl, cnt in program.repeats]
    if not base:
        return []
    roots = _build_tree(base)
    runs, cur = [], [roots[0]]
    for nd in roots[1:]:
        if nd.s == cur[-1].e:
            cur.append(nd)
        else:
            runs.append(cur)
            cur = [nd]
    runs.append(cur)
    out = []
    for run in runs:
        m = len(run)
        if m < 4:
            continue
        S = run[0].s
        for k in range(1, m // 4 + 1):
            cnt = m // k
            P = run[k].s - S
            if any(run[j * k].s != S + j * P for j in range(cnt)):
                continue            # unequal block lengths inside the period
            if S + cnt * P > run[-1].e:
                continue
            if not _rows_periodic(program, S, P, cnt):
                continue
            warm = _snapshot_warm(program.addr, S, P, cnt)
            if warm is None:
                continue
            out.append(_Node(S, P, cnt, [], super_=True, warm=warm))
            break                   # smallest k wins
    return out


# ---------------------------------------------------------------------------
# Stream analysis helpers (module level so :func:`diagnose` can report the
# same judgements the planner makes).
# ---------------------------------------------------------------------------


def _lines_in(addr: np.ndarray, lo: int, hi: int) -> int:
    a = addr[lo:hi]
    a = a[a >= 0]
    return len(np.unique(a >> 5)) if a.size else 0


def _new_lines_steady(addr: np.ndarray, s: int, P: int, reps: int) -> bool:
    """True when super-periods 1..k touch a constant number of lines
    never seen in earlier super-periods (translation-invariant pattern;
    period 0 owns the first-touch of loop-invariant data)."""
    seen: set = set()
    news = []
    for sp in range(min(8, reps)):
        a = addr[s + sp * P: s + (sp + 1) * P]
        cur = set((a[a >= 0] >> 5).tolist())
        news.append(len(cur - seen))
        seen |= cur
    return len(set(news[1:])) <= 1


def reuse_gaps_stationary(addr: np.ndarray, s: int, e: int, P: int,
                          start: int = 2) -> bool:
    """True when the multiset of cross-period line-reuse gaps landing in
    each super-period is the same for every period (first ``start``
    periods own first-touch transients and are exempt).

    This is the translation-invariance the A == B certificate silently
    assumes.  Two streams walking one region at different line rates
    (e.g. a stride-64 load overtaken by a stride-32 store) re-touch
    line ``2k`` at periods ``k`` and ``2k - 1``: every per-line gap is
    unique, but the gap *arriving* at period ``p`` grows with ``p``, so
    the reuse distance crosses the L1 reach somewhere inside the
    extrapolated region — the two measured periods still agree while
    the steady state they certify is not the block's.  Such folds stay
    honest: folded for speed, never certified exact."""
    a = addr[s:e]
    idx = np.flatnonzero(a >= 0)
    if idx.size == 0:
        return True
    lines = (a[idx] >> 5).astype(np.int64)
    per = idx // P
    order = np.argsort(lines, kind="stable")   # trace order within line
    l_s, p_s = lines[order], per[order]
    cross = (l_s[1:] == l_s[:-1]) & (p_s[1:] > p_s[:-1])
    p2 = p_s[1:][cross]                        # period the reuse lands in
    gap = (p_s[1:] - p_s[:-1])[cross]
    keep = p2 >= start
    p2, gap = p2[keep], gap[keep]
    nper = (e - s) // P
    if nper <= start:
        return True
    if p2.size == 0:
        return True
    counts = np.bincount(p2, minlength=nper)[start:]
    if (counts != counts[0]).any():
        return False
    if counts[0] == 0:
        return True
    o = np.lexsort((gap, p2))
    sig = gap[o].reshape(nper - start, counts[0])
    return bool((sig == sig[0]).all())


def _choose_unit(addr: np.ndarray, nd: "_Node", warm_lines: int,
                 units: tuple):
    """Pick the measurement unit for a repeat block, exactly as the planner
    does: the unit whose warm-up + 2 measured super-periods keeps the fewest
    rows, with steady new-line units strongly preferred.  Returns
    ``(unit, reps, warm, key)`` or None when no unit leaves >= 1
    extrapolated period."""
    if nd.super_:
        u, reps, warm = 1, nd.cnt, max(1, nd.warm)
        kept = (warm + 2) * nd.bl
        return ((u, reps, warm, (False, kept))
                if reps >= warm + 3 else None)
    chosen = None
    for u in units:
        if nd.cnt % u:
            continue
        reps = nd.cnt // u
        per_sp = _lines_in(addr, nd.s, nd.s + u * nd.bl)
        warm = max(1, -(-warm_lines // per_sp)) if per_sp else 1
        if reps >= warm + 3:                # >=1 extrapolated period
            steady_u = _new_lines_steady(addr, nd.s, u * nd.bl, reps)
            kept = (warm + 2) * u * nd.bl
            key = (not steady_u, kept)      # steady units first
            if chosen is None or key < chosen[3]:
                chosen = (u, reps, warm, key)
    return chosen


# ---------------------------------------------------------------------------
# Plan construction.
# ---------------------------------------------------------------------------


def _plan_once(program: Program, nodes: list, warm_lines: int, units: tuple,
               exact_outer: bool) -> FoldPlan | None:
    """One planning pass.  ``exact_outer``: the outermost folded block of
    each nest simulates its kept periods in full (children never fold), so
    the measured A and B are the true per-period counters."""
    T = program.num_instructions
    addr = program.addr
    roots = _build_tree(nodes)

    ranges: list[tuple[int, int, int, int, int]] = []   # (lo, hi, w, wa, wb)
    state = {"folds": 0, "supers": 0}
    dropped: list[tuple[int, int]] = []     # extrapolated (unkept) regions

    def emit_range(lo, hi, children, w, wa, wb, in_fold):
        cur = lo
        for ch in children:
            if ch.s > cur:
                ranges.append((cur, ch.s, w, wa, wb))
            emit_node(ch, w, wa, wb, in_fold)
            cur = ch.e
        if cur < hi:
            ranges.append((cur, hi, w, wa, wb))

    def emit_node(nd, w, wa, wb, in_fold):
        # Unit choice (see _choose_unit): synthesised super-periods use the
        # detected k-block span and snapshot warm-up; plain blocks pick the
        # unit whose warm-up + 2 measured super-periods keeps the fewest
        # rows, preferring units whose early super-periods touch a constant
        # number of distinct lines.
        chosen = _choose_unit(addr, nd, warm_lines, units)
        if chosen is None or chosen[3][1] >= 0.95 * (nd.e - nd.s):
            emit_range(nd.s, nd.e, nd.children, w, wa, wb, in_fold)
            return
        u, reps, warm, _ = chosen
        state["folds"] += 1
        if nd.super_:
            state["supers"] += 1
        P = u * nd.bl
        rest = reps - warm - 2
        dropped.append((nd.s + (warm + 2) * P, nd.e))
        if not reuse_gaps_stationary(addr, nd.s, nd.e, P):
            state["non_stationary"] = True
        for sp in range(warm + 2):
            lo = nd.s + sp * P
            hi = lo + P
            if sp < warm:
                f = (w, wa, wb)
            elif sp == warm:                        # measured period A
                f = (w, wa, wb) if in_fold else (w, w, 0)
            else:                                   # measured period B
                m = 1 + rest
                f = (w * m, wa * m, wb * m) if in_fold else (w * m, 0, w)
            if exact_outer:
                ranges.append((lo, hi, *f))         # full, un-nested period
            else:
                kids = [c for c in nd.children if c.s >= lo and c.e <= hi]
                emit_range(lo, hi, kids, *f, in_fold=True)

    emit_range(0, T, roots, 1, 0, 0, False)
    if not state["folds"]:
        return None
    rows = np.concatenate([np.arange(lo, hi, dtype=np.int64)
                           for lo, hi, *_ in ranges])
    w = np.concatenate([np.full(hi - lo, wv, np.int32)
                        for lo, hi, wv, _, _ in ranges])
    wa = np.concatenate([np.full(hi - lo, av, np.int32)
                         for lo, hi, _, av, _ in ranges])
    wb = np.concatenate([np.full(hi - lo, bv, np.int32)
                         for lo, hi, _, _, bv in ranges])
    # Post-loop state divergence check: the simulated trace leaves the
    # caches in period-B-end state, the real trace in last-period state.
    # If any kept row AFTER a folded block touches a line its dropped
    # periods touched, the runtime A == B check cannot see the difference,
    # so the plan must not be certified exact.  Within-loop divergence
    # (non-stationary reuse gaps, see ``reuse_gaps_stationary``) is caught
    # the same way: fold anyway, never certify.
    certifiable = not state.get("non_stationary", False)
    for d_lo, d_hi in dropped:
        tail = rows[np.searchsorted(rows, d_hi):]
        if not tail.size:
            continue
        a_t = addr[tail]
        a_d = addr[d_lo:d_hi]
        t_lines = np.unique(a_t[a_t >= 0] >> 5)
        d_lines = np.unique(a_d[a_d >= 0] >> 5)
        if np.intersect1d(t_lines, d_lines, assume_unique=True).size:
            certifiable = False
            break
    return FoldPlan(rows=rows, weight=w, wa=wa, wb=wb,
                    num_folds=state["folds"], num_rows_full=T,
                    certifiable=certifiable,
                    num_super_periods=state["supers"],
                    exact_outer=exact_outer)


def plan(program: Program, warm_lines: int = 1024,
         units: tuple = (8, 4, 2, 1)) -> FoldPlan | None:
    """Build a fold plan for ``program`` (None when nothing folds).

    ``warm_lines``: cachelines each fold's warm-up must stream before the
    measured periods (default 2x a 16 KB / 32 B-line L1).

    Planning is two-pass: the *nested* pass folds every sufficiently long
    loop (maximum compression); when its certificate fails — nested folds
    perturb the warm-up state, or dropped iterations' lines are reused
    later — the *exact-outer* pass re-plans with only the outermost block
    of each nest folded and its kept periods simulated in full.  The
    certified plan wins; when neither certifies, the nested plan is kept
    (folded for speed, honestly flagged).
    """
    if not program.repeats:
        return None
    base = [_Node(s, bl, cnt, []) for s, bl, cnt in program.repeats]
    nodes = base + detect_super_periods(program)
    nested = _plan_once(program, nodes, warm_lines, units, exact_outer=False)
    if nested is None or nested.certifiable:
        return nested
    exact = _plan_once(program, nodes, warm_lines, units, exact_outer=True)
    if exact is not None and exact.certifiable:
        return exact
    return nested


def diagnose(program: Program, warm_lines: int = 1024,
             units: tuple = (8, 4, 2, 1)) -> list[dict]:
    """Per-block fold diagnostics: why each repeat block does or does not
    certify.

    For every top-level repeat block and every detected multi-block
    super-period, report the planner's unit choice and the two stream
    invariants the A == B certificate rests on:

    - ``stationary``: cross-period line-reuse gaps are translation
      invariant (:func:`reuse_gaps_stationary`) — False is exactly the
      multi-rate-stream condition that keeps a fold honest but uncertified
      (somier's within-step force/integrate streams are the canonical
      case).
    - ``steady_new_lines``: successive super-periods touch a constant
      number of never-seen lines (:func:`_new_lines_steady`).

    ``foldable`` is False when no unit leaves at least one extrapolated
    period after the warm-up (the block is too short for its warm-up, e.g.
    somier at the paper's 2 time steps vs the detector's 4-period minimum).
    The list is ordered by block start row.
    """
    addr = program.addr
    base = [_Node(s, bl, cnt, []) for s, bl, cnt in program.repeats]
    roots = _build_tree(base)
    out = []
    for nd in roots + detect_super_periods(program):
        chosen = _choose_unit(addr, nd, warm_lines, units)
        rec = dict(start=int(nd.s), end=int(nd.e), block_len=int(nd.bl),
                   count=int(nd.cnt), super_period=bool(nd.super_),
                   foldable=chosen is not None)
        if chosen is not None:
            u, reps, warm, _ = chosen
            P = u * nd.bl
            rec.update(
                unit=int(u), reps=int(reps), warm=int(warm),
                stationary=reuse_gaps_stationary(addr, nd.s, nd.e, P),
                steady_new_lines=_new_lines_steady(addr, nd.s, P, reps))
        out.append(rec)
    return sorted(out, key=lambda r: (r["start"], r["super_period"]))
