"""Trace eDSL: assemble RVV-lite vector programs as instruction traces.

A kernel is written once against :class:`Assembler` and yields a
:class:`Program` — dense numpy field arrays consumed by

  * ``core.interpreter``  — functional execution (numeric oracle), and
  * ``core.simulator``    — the cycle-level cVRF / Register Dispersion model.

Hot loops are emitted with :meth:`Assembler.repeat`, which replicates an
instruction block with per-instruction address strides in vectorised numpy
(multi-million-instruction traces assemble in milliseconds, matching how a
compiler emits a strip-mined RVV loop body that reuses the same register
names every iteration).

Every ``repeat`` additionally records *periodicity metadata* on the finished
:class:`Program` (``repeats``: one ``(start, block_len, count)`` triple per
expanded repeat block, including copies replicated by enclosing repeats).
``core.folding`` uses this to simulate only a warm-up plus two measured
periods of each hot loop and extrapolate the cycle counters algebraically —
exact for steady-state traces, replacing lossy prefix truncation.
"""

from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from repro.core import isa

_FIELDS = ("op", "vd", "vs1", "vs2", "addr", "imm", "cost_override")


@dataclasses.dataclass
class Program:
    """A finalized RVV-lite trace plus its memory image."""

    op: np.ndarray            # (T,) int32 opcode
    vd: np.ndarray            # (T,) int32 destination vreg (-1 if none)
    vs1: np.ndarray           # (T,) int32 source 1 (-1 if none)
    vs2: np.ndarray           # (T,) int32 source 2 (-1 if none)
    addr: np.ndarray          # (T,) int64 byte address for memory ops (-1 else)
    imm: np.ndarray           # (T,) float32 scalar immediate
    cost_override: np.ndarray  # (T,) int32, -1 => use the ISA table cost
    memory: np.ndarray        # (M,) float32 initial memory image
    buffers: dict[str, tuple[int, int]]  # name -> (base byte addr, n_f32)
    name: str = "program"
    # Periodicity metadata: (start_row, block_len, count) per expanded
    # ``Assembler.repeat`` block (properly nested or disjoint by construction).
    repeats: list[tuple[int, int, int]] = dataclasses.field(
        default_factory=list)

    @property
    def num_instructions(self) -> int:
        return int(self.op.shape[0])

    def active_vregs(self) -> np.ndarray:
        """Distinct architectural vector registers referenced by the trace."""
        tbl = isa.op_table()
        used = np.concatenate([
            self.vd[tbl["writes_vd"][self.op] | tbl["reads_vd"][self.op]],
            self.vs1[tbl["reads_vs1"][self.op]],
            self.vs2[tbl["reads_vs2"][self.op]],
        ])
        used = used[used >= 0]
        mask_writers = tbl["writes_mask"][self.op]
        out = np.unique(used)
        if mask_writers.any() or np.isin(self.op, list(isa.MASK_READERS)).any():
            out = np.unique(np.concatenate([out, [isa.MASK_REG]]))
        return out

    def vrf_utilization(self) -> float:
        return float(len(self.active_vregs())) / isa.NUM_ARCH_VREGS

    def buffer_view(self, memory: np.ndarray, name: str) -> np.ndarray:
        base, n = self.buffers[name]
        assert base % 4 == 0
        return memory[base // 4: base // 4 + n]


class MemoryMap:
    """32-byte-aligned named buffer allocator building the initial memory."""

    def __init__(self):
        self._cursor = 0
        self._chunks: list[tuple[int, np.ndarray]] = []
        self.buffers: dict[str, tuple[int, int]] = {}

    @staticmethod
    def _align(x: int, a: int = isa.VLEN_BYTES) -> int:
        return (x + a - 1) // a * a

    def alloc(self, name: str, data_or_size) -> int:
        """Allocate a named f32 buffer; returns its base *byte* address."""
        if isinstance(data_or_size, (int, np.integer)):
            data = np.zeros(int(data_or_size), np.float32)
        else:
            data = np.asarray(data_or_size, np.float32).reshape(-1)
        base = self._align(self._cursor)
        self._cursor = base + data.size * 4
        self._chunks.append((base, data))
        self.buffers[name] = (base, data.size)
        return base

    def build(self, extra_bytes: int = 0) -> np.ndarray:
        size = self._align(self._cursor + extra_bytes) // 4
        mem = np.zeros(size, np.float32)
        for base, data in self._chunks:
            mem[base // 4: base // 4 + data.size] = data
        return mem


class Assembler:
    """Builds instruction traces. Registers are plain ints in [0, 32)."""

    def __init__(self, name: str = "program"):
        self.name = name
        self._cols = {f: [] for f in _FIELDS}
        # Per-level address strides: _strides[k] is the stride applied by the
        # (k+1)-th enclosing ``repeat``; one list per level, aligned with the
        # instruction columns.  Levels are created lazily, so nests of any
        # depth (batched conv, multi-head attention) cost nothing shallower
        # kernels.
        self._strides: list[list[int]] = []
        self._segs: list[tuple[int, int, int]] = []   # (start, block_len, n)

    def _set_strides(self, strides) -> None:
        n = len(self._cols["op"]) - 1          # the instruction just emitted
        while len(self._strides) < len(strides):
            self._strides.append([0] * n)
        for lv, col in enumerate(self._strides):
            col.append(int(strides[lv]) if lv < len(strides) else 0)

    @staticmethod
    def _stride_vec(strides, stride, stride2, stride3):
        if strides is not None:
            if stride or stride2 or stride3:
                raise ValueError("pass either strides= or stride/stride2/"
                                 "stride3, not both")
            return tuple(int(s) for s in strides)
        return (stride, stride2, stride3)

    # ---------------------------------------------------------------- emit --
    def _emit(self, op, vd=-1, vs1=-1, vs2=-1, addr=-1, imm=0.0,
              cost=-1, strides=()):
        for r in (vd, vs1, vs2):
            if r != -1 and not (0 <= r < isa.NUM_ARCH_VREGS):
                raise ValueError(f"bad vreg {r}")
        c = self._cols
        c["op"].append(op); c["vd"].append(vd); c["vs1"].append(vs1)
        c["vs2"].append(vs2); c["addr"].append(addr); c["imm"].append(imm)
        c["cost_override"].append(cost)
        self._set_strides(strides)

    # Memory ops.  The per-level stride vector ``strides`` advances ``addr``
    # by ``strides[k]`` per iteration of the (k+1)-th enclosing ``repeat``;
    # the legacy ``stride``/``stride2``/``stride3`` keywords spell the first
    # three levels.
    def vle(self, vd, addr, stride=0, stride2=0, stride3=0, *, strides=None):
        self._emit(isa.VLE, vd=vd, addr=addr,
                   strides=self._stride_vec(strides, stride, stride2,
                                            stride3))

    def vse(self, vs, addr, stride=0, stride2=0, stride3=0, *, strides=None):
        self._emit(isa.VSE, vs1=vs, addr=addr,
                   strides=self._stride_vec(strides, stride, stride2,
                                            stride3))

    def vbcast(self, vd, addr, stride=0, stride2=0, stride3=0, *,
               strides=None):
        self._emit(isa.VBCAST, vd=vd, addr=addr,
                   strides=self._stride_vec(strides, stride, stride2,
                                            stride3))

    def vses(self, vs, addr, stride=0, stride2=0, stride3=0, *,
             strides=None):
        """Store element 0 of vs as a 4-byte scalar (vfmv.f.s + fsw)."""
        self._emit(isa.VSES, vs1=vs, addr=addr,
                   strides=self._stride_vec(strides, stride, stride2,
                                            stride3))

    # Arithmetic.
    def vadd(self, vd, vs1, vs2): self._emit(isa.VADD, vd, vs1, vs2)
    def vsub(self, vd, vs1, vs2): self._emit(isa.VSUB, vd, vs1, vs2)
    def vmul(self, vd, vs1, vs2): self._emit(isa.VMUL, vd, vs1, vs2)
    def vdiv(self, vd, vs1, vs2): self._emit(isa.VDIV, vd, vs1, vs2)
    def vsqrt(self, vd, vs1): self._emit(isa.VSQRT, vd, vs1)
    def vmacc(self, vd, vs1, vs2): self._emit(isa.VFMA, vd, vs1, vs2)
    def vmax(self, vd, vs1, vs2): self._emit(isa.VMAX, vd, vs1, vs2)
    def vmin(self, vd, vs1, vs2): self._emit(isa.VMIN, vd, vs1, vs2)
    def vxor(self, vd, vs1, vs2): self._emit(isa.VXOR, vd, vs1, vs2)
    def vredsum(self, vd, seed, vs2): self._emit(isa.VREDSUM, vd, seed, vs2)
    def vredmax(self, vd, seed, vs2): self._emit(isa.VREDMAX, vd, seed, vs2)
    def vmv(self, vd, vs1): self._emit(isa.VMVV, vd, vs1)
    def vmslt(self, vs1, vs2): self._emit(isa.VCMPLT, -1, vs1, vs2)
    def vmerge(self, vd, vs1, vs2): self._emit(isa.VMERGE, vd, vs1, vs2)
    def vslide1dn(self, vd, vs1, x=0.0):
        self._emit(isa.VSLIDE1DN, vd, vs1, imm=x)
    def vslide1up(self, vd, vs1, x=0.0):
        self._emit(isa.VSLIDE1UP, vd, vs1, imm=x)
    def vmul_sc(self, vd, vs1, x): self._emit(isa.VMULSC, vd, vs1, imm=x)
    def vadd_sc(self, vd, vs1, x): self._emit(isa.VADDSC, vd, vs1, imm=x)

    def scalar(self, n=1):
        """n cycles of scalar bookkeeping (pointer bumps, vsetvli, branch)."""
        self._emit(isa.SCALAR, cost=int(n))

    # -------------------------------------------------------------- repeat --
    @contextlib.contextmanager
    def repeat(self, n: int):
        """Replicate the enclosed block n times, advancing each memory-op
        address by the head of its per-level stride vector per iteration
        (vectorised expansion).

        Repeats nest to ANY depth: after expansion the stride vector shifts
        down one level (``strides[k+1]`` becomes ``strides[k]``), so each
        enclosing repeat consumes the next level — e.g. an inner loop over K
        with level-0 stride 4, a column-chunk loop at level 1, a row loop at
        level 2, and a batch/head loop at level 3."""
        if n < 1:
            raise ValueError("repeat count must be >= 1")
        start = len(self._cols["op"])
        yield
        k = len(self._cols["op"]) - start
        if k == 0:
            return
        block = {f: np.asarray(self._cols[f][start:], dtype=np.float64
                               if f == "imm" else np.int64)
                 for f in _FIELDS}
        sblock = [np.asarray(col[start:], np.int64) for col in self._strides]
        reps = np.arange(n, dtype=np.int64)
        tiled = {f: np.tile(block[f], n) for f in _FIELDS}
        addr = tiled["addr"].copy()
        mem = addr >= 0
        if sblock:
            stride = np.tile(sblock[0], n)
            addr[mem] = addr[mem] + np.repeat(reps, k)[mem] * stride[mem]
        tiled["addr"] = addr
        for f in _FIELDS:
            del self._cols[f][start:]
            self._cols[f].extend(tiled[f].tolist())
        # Shift the stride vector down one level (level 0 was consumed).
        for lv, col in enumerate(self._strides):
            del col[start:]
            if lv + 1 < len(sblock):
                col.extend(np.tile(sblock[lv + 1], n).tolist())
            else:
                col.extend([0] * (k * n))
        if n >= 2:
            # Tiling replicates any repeat blocks recorded inside this one;
            # replicate their metadata too, then record this block itself.
            inner = [s for s in self._segs if s[0] >= start]
            for r in range(1, n):
                self._segs.extend((s0 + r * k, bl, c) for s0, bl, c in inner)
            self._segs.append((start, k, n))

    # ------------------------------------------------------------ finalize --
    def finalize(self, mm: MemoryMap, extra_bytes: int = 0) -> Program:
        c = self._cols
        return Program(
            op=np.asarray(c["op"], np.int32),
            vd=np.asarray(c["vd"], np.int32),
            vs1=np.asarray(c["vs1"], np.int32),
            vs2=np.asarray(c["vs2"], np.int32),
            addr=np.asarray(c["addr"], np.int64),
            imm=np.asarray(c["imm"], np.float32),
            cost_override=np.asarray(c["cost_override"], np.int32),
            memory=mm.build(extra_bytes),
            buffers=dict(mm.buffers),
            name=self.name,
            repeats=sorted(self._segs),
        )
