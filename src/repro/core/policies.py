"""Replacement policies for compact register files / dispersed caches.

The paper's cVRF uses FIFO replacement ("evict the register at the head
pointer", §3.2.2).  We implement FIFO faithfully and add LRU, LFU-lite and
offline-optimal (Belady/OPT) as beyond-paper headroom analyses.  The same
victim-selection functions drive both the cycle simulator (register
granularity) and the serving-layer dispersed KV cache (page granularity) —
the mechanism is the paper's, the granularity is the TPU adaptation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

FIFO = 0      # paper's policy: evict longest-resident entry
LRU = 1       # evict least-recently-used
LFU = 2       # evict least-frequently-used (ties -> oldest)
OPT = 3       # Belady: evict entry with the farthest next use (offline)

POLICY_NAMES = {FIFO: "fifo", LRU: "lru", LFU: "lfu", OPT: "opt"}

INT_MAX = 2**31 - 1
NO_NEXT_USE = 2**31 - 8   # "never used again" sentinel (fits int32)


@dataclasses.dataclass
class CacheState:
    """Per-slot metadata carried through the simulation scan.

    All arrays have shape (n_slots,); ``tags[i] == -1`` means slot i is free.
    """

    tags: jnp.ndarray        # int32 architectural id cached in each slot
    dirty: jnp.ndarray       # bool  modified since fill
    ins_seq: jnp.ndarray     # int32 insertion order   (FIFO)
    last_use: jnp.ndarray    # int32 last access order (LRU)
    freq: jnp.ndarray        # int32 access count      (LFU)
    next_use: jnp.ndarray    # int32 next future use   (OPT)
    pinned: jnp.ndarray      # bool  never evict (v0-analogue entries)

    @staticmethod
    def init(n_slots: int) -> "CacheState":
        z32 = jnp.zeros(n_slots, jnp.int32)
        return CacheState(
            tags=jnp.full(n_slots, -1, jnp.int32),
            dirty=jnp.zeros(n_slots, bool),
            ins_seq=z32, last_use=z32, freq=z32, next_use=z32,
            pinned=jnp.zeros(n_slots, bool),
        )


jax.tree_util.register_dataclass(
    CacheState,
    data_fields=["tags", "dirty", "ins_seq", "last_use", "freq", "next_use",
                 "pinned"],
    meta_fields=[],
)


def select_victim(state: CacheState, policy, valid_mask,
                  lock_a=-1, lock_b=-1) -> jnp.ndarray:
    """Index of the slot to evict among occupied, unpinned, in-capacity slots.

    ``policy`` may be a traced int32 scalar; all four metrics are computed and
    the requested one selected (cheap: slots <= 32/first-level pages).
    ``lock_a``/``lock_b``: tags that must not be evicted (operands of the
    in-flight instruction that were already tag-checked).
    """
    occ = ((state.tags >= 0) & valid_mask & ~state.pinned
           & (state.tags != lock_a) & (state.tags != lock_b))
    inf = jnp.int32(INT_MAX)
    fifo_m = jnp.where(occ, state.ins_seq, inf)
    lru_m = jnp.where(occ, state.last_use, inf)
    # LFU-lite: frequency (capped) with insertion-order tiebreak in low bits.
    lfu_metric = (jnp.minimum(state.freq, 511) * (2**21)
                  + (state.ins_seq & (2**21 - 1)))
    lfu_m = jnp.where(occ, lfu_metric, inf)
    opt_m = jnp.where(occ, -state.next_use, inf)   # farthest next use first
    metric = jnp.select(
        [policy == FIFO, policy == LRU, policy == LFU, policy == OPT],
        [fifo_m, lru_m, lfu_m, opt_m], fifo_m)
    return jnp.argmin(metric)


def on_access(state: CacheState, slot, *, now, next_use, is_write,
              policy) -> CacheState:
    """Metadata update for a hit at ``slot``.

    FIFO deliberately does NOT update recency on hits (paper §3.2.2: the
    circular-FIFO head is the longest-*resident* entry, not least-recent).
    """
    del policy  # all metadata maintained unconditionally; selection picks.
    return dataclasses.replace(
        state,
        dirty=state.dirty.at[slot].set(state.dirty[slot] | is_write),
        last_use=state.last_use.at[slot].set(now),
        freq=state.freq.at[slot].add(1),
        next_use=state.next_use.at[slot].set(next_use),
    )


def on_install(state: CacheState, slot, tag, *, now, seq, next_use,
               is_write, pinned=False) -> CacheState:
    """Install ``tag`` into ``slot`` (after any eviction)."""
    return CacheState(
        tags=state.tags.at[slot].set(tag),
        dirty=state.dirty.at[slot].set(is_write),
        ins_seq=state.ins_seq.at[slot].set(seq),
        last_use=state.last_use.at[slot].set(now),
        freq=state.freq.at[slot].set(1),
        next_use=state.next_use.at[slot].set(next_use),
        pinned=state.pinned.at[slot].set(pinned),
    )


def lookup(state: CacheState, tag, valid_mask):
    """(hit, slot) for ``tag``; slot is the match or an arbitrary index."""
    eq = (state.tags == tag) & valid_mask
    return eq.any(), jnp.argmax(eq)


def free_slot(state: CacheState, valid_mask):
    """(has_free, slot) pointing at an unoccupied in-capacity slot."""
    free = (state.tags < 0) & valid_mask
    return free.any(), jnp.argmax(free)


# ------------------------------------------------------------------ numpy --
# Reference (oracle) implementations used by the numpy interpreter and by
# hypothesis property tests.  Kept deliberately simple and independent of the
# jax versions above.

def np_select_victim(tags, ins_seq, last_use, freq, next_use, pinned,
                     capacity, policy, locked=()) -> int:
    best, best_m = -1, None
    for i in range(capacity):
        if tags[i] < 0 or pinned[i] or tags[i] in locked:
            continue
        m = {FIFO: ins_seq[i], LRU: last_use[i],
             LFU: (freq[i], ins_seq[i]), OPT: -next_use[i]}[policy]
        if best_m is None or m < best_m:
            best, best_m = i, m
    assert best >= 0, "no evictable slot"
    return best
