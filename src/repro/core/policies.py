"""Replacement policies for compact register files / dispersed caches.

The paper's cVRF uses FIFO replacement ("evict the register at the head
pointer", §3.2.2).  We implement FIFO faithfully and add LRU, LFU-lite and
offline-optimal (Belady/OPT) as beyond-paper headroom analyses.  The same
victim-selection functions drive both the cycle simulator (register
granularity) and the serving-layer dispersed KV cache (page granularity) —
the mechanism is the paper's, the granularity is the TPU adaptation.

Layout: all per-slot metadata lives in ONE ``(n_slots, 7)`` int32 matrix
(column constants below), so the fused simulator updates a slot with a
single 7-wide scatter per operand instead of seven per-field scatters —
scatter dispatch dominates the scan step on CPU backends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

FIFO = 0      # paper's policy: evict longest-resident entry
LRU = 1       # evict least-recently-used
LFU = 2       # evict least-frequently-used (ties -> oldest)
OPT = 3       # Belady: evict entry with the farthest next use (offline)

POLICY_NAMES = {FIFO: "fifo", LRU: "lru", LFU: "lfu", OPT: "opt"}

INT_MAX = 2**31 - 1
NO_NEXT_USE = 2**31 - 8   # "never used again" sentinel (fits int32)

# Columns of CacheState.meta.
TAG = 0        # architectural id cached in the slot (-1 = free)
DIRTY = 1      # modified since fill (0/1)
INS_SEQ = 2    # insertion order   (FIFO)
LAST_USE = 3   # last access order (LRU)
FREQ = 4       # access count      (LFU)
NEXT_USE = 5   # next future use   (OPT)
PINNED = 6     # never evict (v0-analogue entries; 0/1)
NUM_COLS = 7


@dataclasses.dataclass
class CacheState:
    """Per-slot metadata carried through the simulation scan."""

    meta: jnp.ndarray        # (n_slots, NUM_COLS) int32

    @staticmethod
    def init(n_slots: int) -> "CacheState":
        meta = jnp.zeros((n_slots, NUM_COLS), jnp.int32)
        return CacheState(meta=meta.at[:, TAG].set(-1))

    @property
    def tags(self) -> jnp.ndarray:
        return self.meta[:, TAG]

    @property
    def dirty(self) -> jnp.ndarray:
        return self.meta[:, DIRTY] == 1


jax.tree_util.register_dataclass(
    CacheState, data_fields=["meta"], meta_fields=[])


def select_victim(state: CacheState, policy, valid_mask,
                  lock_a=-1, lock_b=-1) -> jnp.ndarray:
    """Index of the slot to evict among occupied, unpinned, in-capacity slots.

    ``policy`` may be a traced int32 scalar; all four metrics are computed and
    the requested one selected (cheap: slots <= 32/first-level pages).
    ``lock_a``/``lock_b``: tags that must not be evicted (operands of the
    in-flight instruction that were already tag-checked).
    """
    m = state.meta
    tags = m[:, TAG]
    occ = ((tags >= 0) & valid_mask & (m[:, PINNED] == 0)
           & (tags != lock_a) & (tags != lock_b))
    inf = jnp.int32(INT_MAX)
    fifo_m = jnp.where(occ, m[:, INS_SEQ], inf)
    lru_m = jnp.where(occ, m[:, LAST_USE], inf)
    # LFU-lite: frequency (capped) with insertion-order tiebreak in low bits.
    lfu_metric = (jnp.minimum(m[:, FREQ], 511) * (2**21)
                  + (m[:, INS_SEQ] & (2**21 - 1)))
    lfu_m = jnp.where(occ, lfu_metric, inf)
    opt_m = jnp.where(occ, -m[:, NEXT_USE], inf)   # farthest next use first
    metric = jnp.select(
        [policy == FIFO, policy == LRU, policy == LFU, policy == OPT],
        [fifo_m, lru_m, lfu_m, opt_m], fifo_m)
    return jnp.argmin(metric)


def apply_access(state: CacheState, *, active, raw_hit, hit_slot,
                 install_slot, tag, now, seq, next_use, is_write,
                 pinned=False) -> CacheState:
    """Fused metadata update for one (possibly masked-off) REG access.

    Combines the hit update (recency/frequency/next-use; FIFO deliberately
    does NOT refresh insertion order on hits — paper §3.2.2: the circular
    FIFO head is the longest-*resident* entry) and the miss install into a
    single 7-wide scatter at the hit-or-install slot, gated by ``active``.
    """
    tgt = jnp.where(raw_hit, hit_slot, install_slot)
    old = state.meta[tgt]
    w = jnp.int32(is_write)
    hit_row = jnp.stack([
        old[TAG], old[DIRTY] | w, old[INS_SEQ], now, old[FREQ] + 1,
        jnp.int32(next_use), old[PINNED]])
    ins_row = jnp.stack([
        jnp.int32(tag), w, jnp.int32(seq), jnp.int32(now), jnp.int32(1),
        jnp.int32(next_use), jnp.int32(pinned)])
    new = jnp.where(raw_hit, hit_row, ins_row)
    return CacheState(
        meta=state.meta.at[tgt].set(jnp.where(active, new, old)))


def lookup(state: CacheState, tag, valid_mask):
    """(hit, slot) for ``tag``; slot is the match or an arbitrary index."""
    eq = (state.meta[:, TAG] == tag) & valid_mask
    return eq.any(), jnp.argmax(eq)


def free_slot(state: CacheState, valid_mask):
    """(has_free, slot) pointing at an unoccupied in-capacity slot."""
    free = (state.meta[:, TAG] < 0) & valid_mask
    return free.any(), jnp.argmax(free)


# ------------------------------------------------------------------ numpy --
# Reference (oracle) implementations used by the numpy interpreter and by
# hypothesis property tests.  Kept deliberately simple and independent of the
# jax versions above.

def np_select_victim(tags, ins_seq, last_use, freq, next_use, pinned,
                     capacity, policy, locked=()) -> int:
    best, best_m = -1, None
    for i in range(capacity):
        if tags[i] < 0 or pinned[i] or tags[i] in locked:
            continue
        m = {FIFO: ins_seq[i], LRU: last_use[i],
             LFU: (freq[i], ins_seq[i]), OPT: -next_use[i]}[policy]
        if best_m is None or m < best_m:
            best, best_m = i, m
    assert best >= 0, "no evictable slot"
    return best
