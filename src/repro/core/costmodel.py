"""Analytic hardware area/power model for the cVRF study.

The paper's area/power numbers come from 28 nm synthesis (Cadence flow) of
the Codasip L31 + VPU; synthesis is impossible in this container, so we use
the standard architectural substitute: a component-level area model in
arbitrary calibrated *area units* (au).

Model structure (see Fig 2 / Fig 7 / §4.4.1):
    VPU(n)  = n*REG_AU + ALU0_AU + n*COUPLE_AU  [+ OV(n) if dispersed]
    total   = VPU + SCALAR_AU
  - n*REG_AU      : register storage incl. its port wiring (per register)
  - n*COUPLE_AU   : VRF<->ALU crossbar/routing on the ALU side; this term is
                    what lets the measured VPU saving (53%) exceed the pure
                    VRF-share bound (61% x (1-1/3.5) = 43.6%) — compacting
                    the VRF also shrinks the datapath routing, exactly the
                    congestion effect the paper shows in Fig 7.
  - OV(n)         : dispersion overhead (tag array + comparators + control)
  - dispersed adds one pinned v0 register (n_eff = n + 1).

Calibration: REG_AU+... are solved in closed form from exactly three
published *baseline-and-headline* constraints —
    (1) VRF = 61% of VPU (Fig 2),
    (2) VRF area reduction = 3.5x (§4.4.1),
    (3) VPU area saving = 53% (§4.4.1);
SCALAR_AU then follows from 53% -> 23% total.  The model's *untuned
predictions* (the 23% total, per-width scaling used in Fig 6, per-app power
of Fig 8) are the reproduction, checked in benchmarks/.

Power: dynamic event energies scale with the exercised block's size (VRF
access energy grows with register count - mux depth & bitline load), plus
clock tree (~FF bits) and leakage (~area); activity counts come from the
cycle simulator, so per-application power is simulation-driven.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa

VLEN = isa.VLEN_BITS

# --------------------------------------------------------------------------
# Calibrated constants (closed-form solution of the three constraints).
# --------------------------------------------------------------------------
REG_AU_PER_BIT = 8372.0 / VLEN       # storage + port wiring, per bit
COUPLE_AU_PER_BIT = 1800.4 / VLEN    # VRF<->ALU crossbar, per bit per reg
ALU0_AU = 113670.0                   # 8-lane vector ALU (32b int+bf16 FMA)
TAG_AU_PER_SLOT = 37.0               # 5b tag + valid + dirty + comparator
CTRL_AU = 900.0                      # dispersion control unit / uop FSM
SCALAR_AU = 572749.0                 # L31 scalar core incl. FPU + 2 RFs
# 6T SRAM macro density, for the beyond-paper L1-inclusive trade-off
# (the paper's Fig 2/7 areas exclude L1 macros; the Pareto-frontier study
# and the cluster iso-SRAM-budget sweeps need cache capacity on the same
# axis as the VRF).  Anchor: published 28 nm planar 6T bitcells are
# ~0.12-0.127 um^2 (e.g. TSMC 28 nm HPM as reported in ISSCC'11-era SRAM
# papers), and assembled macros land at ~2x the raw bitcell array once
# decoders/sense-amps/redundancy are in (the periphery constant below).
# The paper gives no absolute um^2 for its flop VRF, only ratios, so the
# calibrated REG_AU_PER_BIT fixes the au scale; a flop + mux/clock load
# in 28 nm is ~4x a 6T bitcell in drawn area, hence the /4.  The old
# TODO(cal) is closed by ``repro.silicon``: these two constants are now
# the pinned derivation of the default ``flop`` macro model (bit-identical
# to this closed form), and per-geometry OpenRAM-style curves
# (``sram6t`` / ``table``) are swappable behind ``l1_sram_area(macro=)``
# and the ``macro_model`` parameter of the area/energy metrics.
SRAM_AU_PER_BIT = REG_AU_PER_BIT / 4.0
SRAM_PERIPHERY_AU = 9000.0           # decoders + sense amps + tag array


@dataclasses.dataclass
class AreaReport:
    vrf: float                        # registers + their routing
    coupling: float                   # VRF<->ALU crossbar share
    vpu_alu: float
    dispersion_overhead: float
    scalar_core: float

    @property
    def vpu(self) -> float:
        return (self.vrf + self.coupling + self.vpu_alu
                + self.dispersion_overhead)

    @property
    def total(self) -> float:
        return self.vpu + self.scalar_core

    def as_dict(self) -> dict:
        return dict(vrf=self.vrf, coupling=self.coupling,
                    vpu_alu=self.vpu_alu,
                    dispersion_overhead=self.dispersion_overhead,
                    scalar_core=self.scalar_core, vpu=self.vpu,
                    total=self.total)


def cpu_area(n_vregs: int, vlen_bits: int = VLEN, n_lanes: int = 8,
             dispersed: bool = False) -> AreaReport:
    """CPU+VPU logic area (excluding L1 SRAM macros, as Fig 7)."""
    n_eff = n_vregs + (1 if dispersed else 0)      # pinned v0
    vrf = n_eff * vlen_bits * REG_AU_PER_BIT
    couple = n_eff * vlen_bits * COUPLE_AU_PER_BIT
    alu = ALU0_AU * (n_lanes / 8.0)
    over = (n_vregs * TAG_AU_PER_SLOT + CTRL_AU) if dispersed else 0.0
    return AreaReport(vrf=vrf, coupling=couple, vpu_alu=alu,
                      dispersion_overhead=over, scalar_core=SCALAR_AU)


def cpu_area_grid(n_vregs, vlen_bits: int = VLEN, n_lanes: int = 8,
                  dispersed=False) -> dict:
    """Vectorized :func:`cpu_area`: ``n_vregs`` / ``dispersed`` may be
    ndarrays (broadcast together) and every component comes back as an
    array of the broadcast shape.  Operation order mirrors the scalar path
    exactly, so grid entries are bit-equal to per-point ``cpu_area`` calls
    (pinned by ``tests/test_metrics.py``)."""
    n_vregs = np.asarray(n_vregs, np.int64)
    dispersed = np.asarray(dispersed, bool)
    n_vregs, dispersed = np.broadcast_arrays(n_vregs, dispersed)
    n_eff = n_vregs + dispersed                       # pinned v0
    vrf = n_eff * vlen_bits * REG_AU_PER_BIT
    couple = n_eff * vlen_bits * COUPLE_AU_PER_BIT
    alu = np.broadcast_to(
        np.asarray(ALU0_AU * (n_lanes / 8.0)), n_vregs.shape)
    over = np.where(dispersed, n_vregs * TAG_AU_PER_SLOT + CTRL_AU, 0.0)
    scalar = np.broadcast_to(np.asarray(SCALAR_AU), n_vregs.shape)
    vpu = vrf + couple + alu + over
    return dict(vrf=vrf, coupling=couple, vpu_alu=alu,
                dispersion_overhead=over, scalar_core=scalar, vpu=vpu,
                total=vpu + scalar)


def l1_sram_area(sets, ways, line_bytes: int = 32, macro=None):
    """L1 data-cache macro area (beyond-paper; excluded from Fig 2/7).
    Vectorized over ``sets``/``ways`` arrays.

    ``macro`` selects a :mod:`repro.silicon` macro model (a registry name
    or a ``MacroModel`` instance) pricing the ``sets * ways`` lines x
    ``line_bytes * 8``-bit geometry; ``None`` keeps the legacy closed
    form, which IS the ``flop`` backend (bit-identical, pinned in
    ``tests/test_silicon.py``)."""
    if macro is not None:
        from repro import silicon   # lazy: silicon sits above the core
        model = silicon.get_macro_model(macro)
        return model.area(
            np.asarray(sets, np.int64) * np.asarray(ways, np.int64),
            line_bytes * 8)
    bits = np.asarray(sets, np.int64) * np.asarray(ways, np.int64) \
        * (line_bytes * 8)
    return bits * SRAM_AU_PER_BIT + SRAM_PERIPHERY_AU


# --------------------------------------------------------------------------
# Analytic cross-check of traced machine-axis sweeps.
# --------------------------------------------------------------------------

# Counters that latency parameters may change.  Everything else is decided
# by the replacement machinery, whose metadata is slot-grid-timestamped and
# therefore machine-latency-invariant.
TIMING_COUNTERS = ("cycles", "stall_cycles")


def check_machine_affine(counters: dict, machines, timing=TIMING_COUNTERS,
                         mem_slope_floor=None) -> dict:
    """Analytic conformance check of a machine-swept counter grid.

    The simulator's latency parameters (``l1_hit_cycles``,
    ``uop_hit_cycles``, ``mem_latency``) enter only the cycle arithmetic,
    never a hit/miss/eviction decision, so for counters on a trailing
    machine axis of M points (from ``simulate_grid(..., MachineSweep)``):

      * every non-timing counter must be *constant* along the machine axis;
      * ``cycles`` and ``stall_cycles`` must be exactly affine in the three
        latencies, with non-negative integer coefficients; the
        ``mem_latency`` coefficient counts memory transfers, so it is at
        least ``l1_misses`` (writebacks add to it).

    Raises AssertionError (explicitly, so the check survives ``python -O``)
    on any violation; returns the integer coefficient arrays ``{counter:
    (const, a_l1hit, a_uop, a_mem)}`` with leading shape equal to the
    grid's non-machine dimensions.  A latency held constant across the
    sweep is not identifiable: its coefficient is reported as 0 and its
    contribution folds into ``const``.  This is the closed-form cross-check
    that a traced machine sweep agrees with the per-point machine model —
    no re-simulation needed.

    ``timing`` names the counters the latencies may change (default
    :data:`TIMING_COUNTERS`; the cluster engine adds
    ``contention_stalls``), and ``mem_slope_floor`` overrides the default
    ``l1_misses`` floor on the ``mem_latency`` slope of ``cycles`` — a
    shared L2 converts hits into static-latency transfers, so cluster
    counters pass ``l1_misses - l2_hits`` (see
    :func:`repro.cluster.engine.check_cluster_affine`).
    """
    M = len(machines)
    axes = (np.ones(M), np.asarray(machines.l1_hit_cycles, np.float64),
            np.asarray(machines.uop_hit_cycles, np.float64),
            np.asarray(machines.mem_latency, np.float64))
    # Only the intercept plus latencies that actually vary enter the fit;
    # a constant column would make the design rank-deficient and let the
    # min-norm solution smear the intercept into meaningless slopes.
    ident = [0] + [i for i in (1, 2, 3) if np.unique(axes[i]).size > 1]
    design = np.stack([axes[i] for i in ident], axis=1)     # (M, k)
    if np.linalg.matrix_rank(design) < len(ident):
        raise AssertionError(
            "machine sweep axes are collinear — per-latency coefficients "
            "are not identifiable; decorrelate the sweep grid")
    for name, v in counters.items():
        if name in timing or name in ("hit_rate", "event_scale",
                                      "fold_exact"):
            continue
        v = np.asarray(v)
        if not (v == v[..., :1]).all():
            raise AssertionError(
                f"counter {name!r} varies along the machine axis — latency "
                "parameters leaked into a replacement decision")
    coeffs = {}
    pinv = np.linalg.pinv(design)                     # (k, M)
    for name in timing:
        y = np.asarray(counters[name], np.float64)    # (..., M)
        c = np.einsum("km,...m->...k", pinv, y)       # (..., k)
        resid = np.einsum("mk,...k->...m", design, c) - y
        if not np.abs(resid).max() < 0.5:
            raise AssertionError(
                f"counter {name!r} is not affine in the machine latencies "
                f"(max residual {np.abs(resid).max():.3f})")
        full = np.zeros(y.shape[:-1] + (4,))
        full[..., ident] = c
        coeffs[name] = np.rint(full).astype(np.int64)
    # The mem_latency slope of total cycles counts memory transfers:
    # >= l1_misses, identifiable only when the sweep varies mem_latency.
    if 3 in ident:
        slope = coeffs["cycles"][..., 3]
        if mem_slope_floor is None:
            mem_slope_floor = np.asarray(counters["l1_misses"])[..., 0]
        if not (slope >= np.asarray(mem_slope_floor)).all():
            raise AssertionError(
                "cycles' mem_latency slope fell below its transfer floor "
                "(l1_misses, or l1_misses - l2_hits for clusters)")
    return coeffs


# --------------------------------------------------------------------------
# Power model.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Per-event dynamic energies (pJ-equivalent) and static coefficients."""

    e_vrf_access_per_reg: float = 0.02   # per resident register per access
    e_alu_op: float = 14.0               # one 8-lane vector op
    e_scalar_op: float = 6.0
    e_l1_access: float = 12.0            # 32 B L1 hit
    e_mem_access: float = 70.0           # 32 B main-memory transfer
    leak_per_au: float = 1e-5            # static power per area unit
    clock_per_ff_bit: float = 0.0005     # clock tree per FF bit
    p_base: float = 30.0                 # fetch/PLL/IO floor (VRF-invariant)


DEFAULT_POWER = PowerParams()


def application_power(counters: dict, n_vregs: int, cycles: float,
                      n_lanes: int = 8, dispersed: bool = False,
                      pp: PowerParams = DEFAULT_POWER) -> dict:
    """Average-power estimate for one application run (model units).

    ``counters`` from ``simulator.simulate_*``: the hit/miss/spill/fill
    traffic the mechanism adds is charged at L1/memory energy, so the
    power saving is a *net* of smaller-VRF gains minus dispersion traffic.
    """
    area = cpu_area(n_vregs, n_lanes=n_lanes, dispersed=dispersed)
    n_eff = n_vregs + (1 if dispersed else 0)
    reg_ev = float(counters["reg_reads"] + counters["reg_writes"])
    l1_ev = float(counters["l1_hits"] + counters["mem_reads"]
                  + counters["mem_writes"])
    mem_ev = float(counters["l1_misses"])
    alu_ev = float(counters["reg_writes"])
    cyc = max(float(counters["cycles"]), 1.0)

    dyn = (reg_ev * pp.e_vrf_access_per_reg * n_eff
           + alu_ev * pp.e_alu_op
           + cyc * 0.35 * pp.e_scalar_op
           + l1_ev * pp.e_l1_access
           + mem_ev * pp.e_mem_access) / cyc
    clock = n_eff * VLEN * pp.clock_per_ff_bit
    leak = area.total * pp.leak_per_au
    return dict(dynamic=dyn, clock=clock, leakage=leak, base=pp.p_base,
                total=pp.p_base + dyn + clock + leak)


def application_power_grid(counters: dict, n_vregs, n_lanes: int = 8,
                           dispersed=False,
                           pp: PowerParams = DEFAULT_POWER) -> dict:
    """Vectorized :func:`application_power` over a whole counter grid.

    ``counters`` holds counter-name -> ndarray grids (e.g. straight from a
    :class:`repro.api.SweepResult`); ``n_vregs`` / ``dispersed`` broadcast
    against them.  Term order mirrors the scalar path exactly, so every
    grid entry is bit-equal to a per-point ``application_power`` call
    (pinned by ``tests/test_metrics.py``) — this is what replaced fig8's
    per-application Python loop."""
    n_vregs = np.asarray(n_vregs, np.int64)
    dispersed = np.asarray(dispersed, bool)
    area_total = cpu_area_grid(n_vregs, n_lanes=n_lanes,
                               dispersed=dispersed)["total"]
    n_eff = n_vregs + dispersed
    as_f = lambda v: np.asarray(v, np.float64)  # noqa: E731
    reg_ev = as_f(counters["reg_reads"] + counters["reg_writes"])
    l1_ev = as_f(counters["l1_hits"] + counters["mem_reads"]
                 + counters["mem_writes"])
    mem_ev = as_f(counters["l1_misses"])
    alu_ev = as_f(counters["reg_writes"])
    cyc = np.maximum(as_f(counters["cycles"]), 1.0)

    dyn = (reg_ev * pp.e_vrf_access_per_reg * n_eff
           + alu_ev * pp.e_alu_op
           + cyc * 0.35 * pp.e_scalar_op
           + l1_ev * pp.e_l1_access
           + mem_ev * pp.e_mem_access) / cyc
    clock = n_eff * VLEN * pp.clock_per_ff_bit
    leak = area_total * pp.leak_per_au
    total = pp.p_base + dyn + clock + leak
    base = np.asarray(pp.p_base)
    dyn, clock, leak, base, total = np.broadcast_arrays(
        dyn, clock, leak, base, total)
    return dict(dynamic=dyn, clock=clock, leakage=leak, base=base,
                total=total)
