"""Register Dispersion core: the paper's contribution as composable modules.

Sweeps are best driven through the declarative front door one layer up —
``repro.api`` (Sweep / Session / SweepResult, see ``docs/api.md``); the
modules here are the engine room it is built on.

Public API:
  trace.Assembler / trace.MemoryMap / trace.Program   — RVV-lite trace eDSL
  interpreter.run / interpreter.run_dispersed          — functional oracles
  simulator.prepare / simulate_grid / simulate_one     — cycle-level cVRF model
  simulator.MachineSweep                               — traced machine axes
  simulator.simulate_sweep                             — DEPRECATED shim
                                                        (-> repro.api)
  folding.plan                                         — exact periodic folding
  policies.FIFO / LRU / LFU / OPT                      — replacement policies
  planner.min_registers_for_hit_rate / policy_headroom — working-set planning
  costmodel.cpu_area / application_power               — analytic 28nm model
  costmodel.check_machine_affine                       — machine-axis check
"""

from repro.core import (costmodel, events, folding, interpreter, isa,
                        planner, policies, simulator, trace)
from repro.core.simulator import (MachineParams, MachineSweep, PreparedTrace,
                                  SweepConfig, prepare, simulate_grid,
                                  simulate_one, simulate_sweep)
from repro.core.trace import Assembler, MemoryMap, Program

__all__ = [
    "costmodel", "events", "folding", "interpreter", "isa", "planner",
    "policies", "simulator", "trace", "MachineParams", "MachineSweep",
    "PreparedTrace", "SweepConfig", "prepare", "simulate_grid",
    "simulate_one", "simulate_sweep", "Assembler", "MemoryMap", "Program",
]
