"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to auto: True when no TPU is present (this container
is CPU-only; interpret mode executes the kernel body with jnp semantics),
False on real TPU hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispersed_gemm as _dg
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as ref


def _auto_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def flash_attention(q, k, v, *, causal: bool = False,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """FlashAttention-2 with GQA support: k/v may have fewer heads than q
    (q heads must be a multiple); they are expanded before the kernel."""
    if interpret is None:
        interpret = _auto_interpret()
    hq, hkv = q.shape[1], k.shape[1]
    if hkv != hq:
        if hkv == 0 or hq % hkv:
            raise ValueError(
                f"GQA needs q heads ({hq}) to be a multiple of k/v heads "
                f"({hkv})")
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def matmul(a, b, *, working_set: int = 4, block_m: int = 128,
           block_k: int = 512, interpret: bool | None = None):
    """Grouped (compact-working-set) GEMM — the recommended schedule."""
    if interpret is None:
        interpret = _auto_interpret()
    return _dg.matmul_grouped(a, b, block_m=block_m, block_k=block_k,
                              working_set=working_set, interpret=interpret)


def matmul_dispersed(a, b, *, block_m: int = 128, block_k: int = 512,
                     interpret: bool | None = None):
    """Fully-dispersed (HBM round-trip accumulators) GEMM — the W=0 extreme."""
    if interpret is None:
        interpret = _auto_interpret()
    return _dg.matmul_dispersed(a, b, block_m=block_m, block_k=block_k,
                                interpret=interpret)


hbm_traffic_model = _dg.hbm_traffic_model
flash_traffic_model = _fa.hbm_traffic_model

# Schedule geometries (grid + index maps shared with the pallas_calls) for
# the instrumented traffic count — see repro.kernels.traffic.
grouped_schedule = _dg.grouped_schedule
dispersed_schedule = _dg.dispersed_schedule
flash_schedule = _fa.flash_schedule
