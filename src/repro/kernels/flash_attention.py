"""FlashAttention-2 Pallas TPU kernel with an O(1) VMEM working set.

TPU adaptation of the paper's insight (DESIGN.md §2.B): the running-max /
normaliser / output-accumulator tiles form a *compact physical working set*
in VMEM (the cVRF analogue), while the S x S score matrix — the
"architectural state" — is never materialised; K/V stream through VMEM
blocks.  Grid = (batch*heads, q blocks, kv blocks) with the kv dimension
innermost so the accumulator scratch persists across kv steps.

BlockSpec tiling (all MXU-aligned, multiples of (8,128) for f32 /
(16,128) for bf16):
  q:   (1, block_q, d)     indexed by (bh, iq)
  k/v: (1, block_k, d)     indexed by (bh, ik)
  out: (1, block_q, d)     written on the last kv step
VMEM scratch: acc (block_q, d) f32, m/l (block_q, MIN_LANE) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import traffic

NEG_INF = -1e30
LANES = 128
ACC_BYTES = 4      # m/l/acc scratch is f32


def _check_blocks(sq: int, sk: int, *, block_q: int, block_k: int):
    """Clamp blocks to the sequence lengths, then require exact tiling —
    raising ``ValueError``s that name the offending dimension instead of
    bare asserts (which vanish under ``python -O``)."""
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q:
        raise ValueError(
            f"query length sq={sq} is not divisible by block_q={block_q}; "
            f"legal block_q values divide sq (e.g. "
            f"{[d for d in (32, 64, 128, 256) if sq % d == 0]})")
    if sk % block_k:
        raise ValueError(
            f"key length sk={sk} is not divisible by block_k={block_k}; "
            f"legal block_k values divide sk (e.g. "
            f"{[d for d in (32, 64, 128, 256) if sk % d == 0]})")
    return block_q, block_k, sq // block_q, sk // block_k


def _flash_maps():
    """BlockSpec index maps — shared with :func:`flash_schedule` so the
    traffic count walks exactly the grid the kernel runs."""
    q = lambda bh_, iq, ik: (bh_, iq, 0)
    kv = lambda bh_, iq, ik: (bh_, ik, 0)
    o = lambda bh_, iq, ik: (bh_, iq, 0)
    return q, kv, o


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  num_kv_blocks: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    run = True
    if causal:
        # Skip fully-masked blocks (query strictly above the diagonal).
        run = k_start < q_start + block_q

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[:, :1]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)           # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_new = l_scr[:, :1] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = False,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, H, S, D) with equal H (caller expands GQA). Returns same
    shape as q.  Set ``interpret=True`` to run on CPU (tests/oracle sweeps).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if k.shape[1] != h:
        raise ValueError(
            f"flash_attention needs equal head counts, got q heads={h} vs "
            f"k/v heads={k.shape[1]} (use ops.flash_attention for GQA)")
    if scale is None:
        scale = float(d) ** -0.5
    block_q, block_k, nq, nk = _check_blocks(
        sq, sk, block_q=block_q, block_k=block_k)
    bh = b * h
    qr = q.reshape(bh, sq, d)
    kr = k.reshape(bh, sk, d)
    vr = v.reshape(bh, sk, d)
    q_map, kv_map, o_map = _flash_maps()

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), o_map),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),      # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),      # normaliser
            pltpu.VMEM((block_q, d), jnp.float32),          # output acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)


# ---------------------------------------------------------------------------
# Traffic geometry: the measured side of the roofline's model check.
# ---------------------------------------------------------------------------


def flash_schedule(b: int, h: int, sq: int, sk: int, d: int, *,
                   block_q: int, block_k: int,
                   bytes_per_el: int = 2) -> traffic.Schedule:
    """The flash schedule's grid + operand parts, built from the same
    index maps as :func:`flash_attention`.  Q and O move once; K/V are
    re-streamed once per q block (the price of the O(1) working set)."""
    block_q, block_k, nq, nk = _check_blocks(
        sq, sk, block_q=block_q, block_k=block_k)
    q_map, kv_map, o_map = _flash_maps()
    return traffic.Schedule(
        grid=(b * h, nq, nk),
        parts=(
            traffic.Part("q", block_q * d * bytes_per_el, q_map, "in"),
            traffic.Part("k", block_k * d * bytes_per_el, kv_map, "in"),
            traffic.Part("v", block_k * d * bytes_per_el, kv_map, "in"),
            traffic.Part("o", block_q * d * bytes_per_el, o_map, "out"),
        ))


def hbm_traffic_model(b: int, h: int, sq: int, sk: int, d: int, *,
                      block_q: int, block_k: int,
                      bytes_per_el: int = 2) -> dict:
    """Closed-form HBM bytes for attention schedules (roofline input).

    flash: Q and O once; the K/V panels re-streamed once per q block —
    kv traffic scales as nq = sq/block_q (larger q blocks = a larger VMEM
    working set = fewer K/V re-fetches: the same register/traffic
    trade-off as the grouped GEMM).
    materialized: the dispersed extreme — the (sq, sk) score matrix is
    spilled to and refilled from HBM at f32 width, as a non-fused
    attention would.
    ideal: every operand exactly once.
    """
    block_q, block_k, nq, nk = _check_blocks(
        sq, sk, block_q=block_q, block_k=block_k)
    bh = b * h
    q_bytes = bh * sq * d * bytes_per_el
    kv_bytes = bh * sk * d * bytes_per_el           # one of K or V
    o_bytes = q_bytes
    flash = q_bytes + o_bytes + 2 * nq * kv_bytes
    scores = bh * sq * sk * ACC_BYTES
    materialized = q_bytes + o_bytes + 2 * kv_bytes + 2 * scores
    ideal = q_bytes + o_bytes + 2 * kv_bytes
    return dict(flash=flash, materialized=materialized, ideal=ideal,
                vmem_acc_bytes=(block_q * d + 2 * block_q * LANES)
                * ACC_BYTES)
