"""FlashAttention-2 Pallas TPU kernel with an O(1) VMEM working set.

TPU adaptation of the paper's insight (DESIGN.md §2.B): the running-max /
normaliser / output-accumulator tiles form a *compact physical working set*
in VMEM (the cVRF analogue), while the S x S score matrix — the
"architectural state" — is never materialised; K/V stream through VMEM
blocks.  Grid = (batch*heads, q blocks, kv blocks) with the kv dimension
innermost so the accumulator scratch persists across kv steps.

BlockSpec tiling (all MXU-aligned, multiples of (8,128) for f32 /
(16,128) for bf16):
  q:   (1, block_q, d)     indexed by (bh, iq)
  k/v: (1, block_k, d)     indexed by (bh, ik)
  out: (1, block_q, d)     written on the last kv step
VMEM scratch: acc (block_q, d) f32, m/l (block_q, MIN_LANE) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  num_kv_blocks: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    run = True
    if causal:
        # Skip fully-masked blocks (query strictly above the diagonal).
        run = k_start < q_start + block_q

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[:, :1]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)           # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_new = l_scr[:, :1] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = False,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, H, S, D) with equal H (caller expands GQA). Returns same
    shape as q.  Set ``interpret=True`` to run on CPU (tests/oracle sweeps).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if scale is None:
        scale = float(d) ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    bh = b * h
    qr = q.reshape(bh, sq, d)
    kr = k.reshape(bh, sk, d)
    vr = v.reshape(bh, sk, d)
    nq = sq // block_q
    nk = sk // block_k

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, iq, ik: (bh_, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, iq, ik: (bh_, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, iq, ik: (bh_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh_, iq, ik: (bh_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),      # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),      # normaliser
            pltpu.VMEM((block_q, d), jnp.float32),          # output acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
