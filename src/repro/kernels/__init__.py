"""Pallas TPU kernels (validated in interpret mode on CPU; see ops.py)."""

from repro.kernels import (dispersed_gemm, flash_attention, ops, ref,
                           rmsnorm, traffic)

__all__ = ["dispersed_gemm", "flash_attention", "ops", "ref",
           "rmsnorm", "traffic"]
