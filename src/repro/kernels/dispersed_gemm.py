"""Dispersed-accumulator GEMM: the cVRF trade-off at VMEM granularity.

TPU adaptation of Register Dispersion (DESIGN.md §2.B).  Output tiles of
C = A @ B play the role of *architectural vector registers*; the VMEM
accumulator scratch plays the role of the *compact physical register file*.

Two schedules expose the paper's trade-off:

  * ``matmul_grouped(working_set=W)`` — a compact set of W row-tile
    accumulators is VMEM-resident while the full K reduction completes for
    the group ("registers cached in the cVRF"): grid (groups, k, W).  The B
    panel is fetched once per (group, k) and reused W times, so B HBM
    traffic scales as 1/W — more physical registers => less memory traffic,
    exactly the paper's Fig 4 economics at a different level of the
    hierarchy.  VMEM cost grows linearly in W (the cVRF area analogue).

  * ``matmul_dispersed()`` — the W=0 extreme: every accumulator access
    round-trips through HBM (grid (k, m) with the output block revisited
    per k step), i.e. every "register access" is a spill+fill.

``hbm_traffic_model`` gives the closed-form bytes for the roofline tables;
``grouped_schedule`` / ``dispersed_schedule`` expose the grids and the
*same index-map lambdas* the ``pallas_call``s are built from, so
:func:`repro.kernels.traffic.count` can cross-check the closed form
against the schedule the hardware actually runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import traffic

ACC_BYTES = 4      # both schedules accumulate in f32


def _check_tiles(m: int, k: int, k2: int, *, block_m: int, block_k: int):
    """Shared kernel/model legality: clamp blocks, then require exact
    tiling.  Raises ``ValueError`` naming the offending dimension (bare
    asserts vanish under ``python -O`` and are useless from jit traces)."""
    if k != k2:
        raise ValueError(
            f"contraction mismatch: a has k={k} columns but b has k={k2} "
            f"rows")
    block_m = min(block_m, m)
    block_k = min(block_k, k)
    if block_m <= 0 or block_k <= 0:
        raise ValueError(
            f"block_m/block_k must be positive, got ({block_m}, {block_k})")
    if m % block_m:
        raise ValueError(
            f"m={m} is not divisible by block_m={block_m}; legal block_m "
            f"values divide m (e.g. {[d for d in (8, 16, 32, 64, 128, 256) if m % d == 0]})")
    if k % block_k:
        raise ValueError(
            f"k={k} is not divisible by block_k={block_k}; legal block_k "
            f"values divide k (e.g. {[d for d in (64, 128, 256, 512) if k % d == 0]})")
    return block_m, block_k, m // block_m, k // block_k


def _check_working_set(working_set: int, nm: int) -> tuple[int, int]:
    """Clamp W to the tile count, then require it to divide ``nm`` —
    the grouped grid is (groups, k, W) with groups = nm / W."""
    if working_set < 1:
        raise ValueError(
            f"working_set must be >= 1, got {working_set} (use "
            f"matmul_dispersed for the W=0 extreme)")
    w = min(working_set, nm)
    if nm % w:
        raise ValueError(
            f"working_set={working_set} (clamped to {w}) does not divide "
            f"the m-tile count nm={nm}; legal working sets: "
            f"{[d for d in range(1, nm + 1) if nm % d == 0]}")
    return w, nm // w


def _grouped_maps(w: int):
    """The grouped schedule's BlockSpec index maps — single source of
    truth for both ``matmul_grouped`` and its traffic schedule."""
    a = lambda g, ik, iw: (g * w + iw, ik)
    b = lambda g, ik, iw: (ik, 0)
    o = lambda g, ik, iw: (g * w + iw, 0)
    return a, b, o


def _dispersed_maps():
    a = lambda ik, im: (im, ik)
    b = lambda ik, im: (ik, 0)
    o = lambda ik, im: (im, 0)
    return a, b, o


def _grouped_kernel(a_ref, b_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(1)
    iw = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[iw] = jnp.zeros_like(acc_scr[iw])

    acc_scr[iw] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _writeback():                      # "eviction" at end of reduction
        o_ref[...] = acc_scr[iw].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "working_set", "interpret"))
def matmul_grouped(a, b, *, block_m: int = 128, block_k: int = 512,
                   working_set: int = 4, interpret: bool = False):
    """C = A @ B with a compact, VMEM-resident accumulator working set.

    Grid (groups, k, w), k middle: for each group of ``working_set`` M-tiles
    the full K reduction runs before moving on; the B panel block index
    depends only on k, so Pallas fetches it once per (group, k) and the
    pipeline reuses it across the W inner steps.
    """
    m, k = a.shape
    k2, n = b.shape
    block_m, block_k, nm, nk = _check_tiles(
        m, k, k2, block_m=block_m, block_k=block_k)
    w, groups = _check_working_set(working_set, nm)
    a_map, b_map, o_map = _grouped_maps(w)

    out = pl.pallas_call(
        functools.partial(_grouped_kernel, nk=nk),
        grid=(groups, nk, w),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_map),
            pl.BlockSpec((block_k, n), b_map),
        ],
        out_specs=pl.BlockSpec((block_m, n), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((w, block_m, n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out


def _dispersed_kernel(a_ref, b_ref, o_ref, *, nk: int):
    ik = pl.program_id(0)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The output tile was just refetched from HBM (a "fill"); accumulate and
    # let the pipeline spill it back when the grid moves on.
    o_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(o_ref.dtype), b_ref[...].astype(o_ref.dtype),
        (((1,), (0,)), ((), ())), preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "interpret"))
def matmul_dispersed(a, b, *, block_m: int = 128, block_k: int = 512,
                     interpret: bool = False):
    """The no-cache extreme: every accumulator revisit spills/fills HBM.

    Grid (k, m) with k outermost: each output tile is written back and
    refetched on every k step (2*M*N*nk bytes of accumulator traffic).
    Accumulation is carried in f32 output storage.
    """
    m, k = a.shape
    k2, n = b.shape
    block_m, block_k, nm, nk = _check_tiles(
        m, k, k2, block_m=block_m, block_k=block_k)
    a_map, b_map, o_map = _dispersed_maps()

    out = pl.pallas_call(
        functools.partial(_dispersed_kernel, nk=nk),
        grid=(nk, nm),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_map),
            pl.BlockSpec((block_k, n), b_map),
        ],
        out_specs=pl.BlockSpec((block_m, n), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out.astype(a.dtype)


# ---------------------------------------------------------------------------
# Traffic geometry: the measured side of the roofline's model check.
# ---------------------------------------------------------------------------


def grouped_schedule(m: int, n: int, k: int, *, block_m: int, block_k: int,
                     working_set: int,
                     bytes_per_el: int = 2) -> traffic.Schedule:
    """The grouped schedule's grid + operand parts, built from the same
    index maps as ``matmul_grouped`` (A/B stream in at the input width;
    C is a pure output — the accumulator lives in VMEM scratch)."""
    block_m, block_k, nm, nk = _check_tiles(
        m, k, k, block_m=block_m, block_k=block_k)
    w, groups = _check_working_set(working_set, nm)
    a_map, b_map, o_map = _grouped_maps(w)
    return traffic.Schedule(
        grid=(groups, nk, w),
        parts=(
            traffic.Part("a", block_m * block_k * bytes_per_el, a_map, "in"),
            traffic.Part("b", block_k * n * bytes_per_el, b_map, "in"),
            traffic.Part("c", block_m * n * bytes_per_el, o_map, "out"),
        ))


def dispersed_schedule(m: int, n: int, k: int, *, block_m: int,
                       block_k: int,
                       bytes_per_el: int = 2) -> traffic.Schedule:
    """The dispersed schedule's geometry: C is an HBM-resident accumulator
    (kind ``"acc"``) — every revisit is a fill + spill at f32 width."""
    block_m, block_k, nm, nk = _check_tiles(
        m, k, k, block_m=block_m, block_k=block_k)
    a_map, b_map, o_map = _dispersed_maps()
    return traffic.Schedule(
        grid=(nk, nm),
        parts=(
            traffic.Part("a", block_m * block_k * bytes_per_el, a_map, "in"),
            traffic.Part("b", block_k * n * bytes_per_el, b_map, "in"),
            traffic.Part("c", block_m * n * ACC_BYTES, o_map, "acc"),
        ))


def hbm_traffic_model(m: int, n: int, k: int, *, block_m: int, block_k: int,
                      working_set: int, bytes_per_el: int = 2) -> dict:
    """Closed-form HBM bytes for the two schedules (roofline input).

    grouped: A once, B once per group (= nm/W fetches of the full panel),
    C written once — all at the input element width (the accumulator stays
    in VMEM scratch).
    dispersed: A once, B once (reused across m at fixed k), C spilled AND
    filled on each of the nk k-steps at the f32 accumulator width.

    Legality mirrors the kernels: blocks are clamped to the problem dims,
    tiling must be exact, and ``working_set`` (after clamping to the m-tile
    count) must divide it — ``matmul_grouped`` rejects exactly the same
    configurations, so the model can never quote traffic for a schedule
    the kernel refuses to run.
    """
    block_m, block_k, nm, nk = _check_tiles(
        m, k, k, block_m=block_m, block_k=block_k)
    w, groups = _check_working_set(working_set, nm)
    grouped = (m * k + groups * k * n + m * n) * bytes_per_el
    dispersed = (m * k + k * n) * bytes_per_el + 2 * m * n * nk * ACC_BYTES
    ideal = (m * k + k * n + m * n) * bytes_per_el
    return dict(grouped=grouped, dispersed=dispersed, ideal=ideal,
                vmem_acc_bytes=w * block_m * n * ACC_BYTES)
