"""Dispersed-accumulator GEMM: the cVRF trade-off at VMEM granularity.

TPU adaptation of Register Dispersion (DESIGN.md §2.B).  Output tiles of
C = A @ B play the role of *architectural vector registers*; the VMEM
accumulator scratch plays the role of the *compact physical register file*.

Two schedules expose the paper's trade-off:

  * ``matmul_grouped(working_set=W)`` — a compact set of W row-tile
    accumulators is VMEM-resident while the full K reduction completes for
    the group ("registers cached in the cVRF"): grid (groups, k, W).  The B
    panel is fetched once per (group, k) and reused W times, so B HBM
    traffic scales as 1/W — more physical registers => less memory traffic,
    exactly the paper's Fig 4 economics at a different level of the
    hierarchy.  VMEM cost grows linearly in W (the cVRF area analogue).

  * ``matmul_dispersed()`` — the W=0 extreme: every accumulator access
    round-trips through HBM (grid (k, m) with the output block revisited
    per k step), i.e. every "register access" is a spill+fill.

``hbm_traffic_model`` gives the closed-form bytes for the roofline tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _grouped_kernel(a_ref, b_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(1)
    iw = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[iw] = jnp.zeros_like(acc_scr[iw])

    acc_scr[iw] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _writeback():                      # "eviction" at end of reduction
        o_ref[...] = acc_scr[iw].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "working_set", "interpret"))
def matmul_grouped(a, b, *, block_m: int = 128, block_k: int = 512,
                   working_set: int = 4, interpret: bool = False):
    """C = A @ B with a compact, VMEM-resident accumulator working set.

    Grid (groups, k, w), k middle: for each group of ``working_set`` M-tiles
    the full K reduction runs before moving on; the B panel block index
    depends only on k, so Pallas fetches it once per (group, k) and the
    pipeline reuses it across the W inner steps.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    block_m = min(block_m, m)
    block_k = min(block_k, k)
    assert m % block_m == 0 and k % block_k == 0
    nm, nk = m // block_m, k // block_k
    w = min(working_set, nm)
    assert nm % w == 0
    groups = nm // w

    out = pl.pallas_call(
        functools.partial(_grouped_kernel, nk=nk),
        grid=(groups, nk, w),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda g, ik, iw, w=w: (g * w + iw, ik)),
            pl.BlockSpec((block_k, n), lambda g, ik, iw: (ik, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n),
                               lambda g, ik, iw, w=w: (g * w + iw, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((w, block_m, n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out


def _dispersed_kernel(a_ref, b_ref, o_ref, *, nk: int):
    ik = pl.program_id(0)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The output tile was just refetched from HBM (a "fill"); accumulate and
    # let the pipeline spill it back when the grid moves on.
    o_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(o_ref.dtype), b_ref[...].astype(o_ref.dtype),
        (((1,), (0,)), ((), ())), preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "interpret"))
def matmul_dispersed(a, b, *, block_m: int = 128, block_k: int = 512,
                     interpret: bool = False):
    """The no-cache extreme: every accumulator revisit spills/fills HBM.

    Grid (k, m) with k outermost: each output tile is written back and
    refetched on every k step (2*M*N*nk bytes of accumulator traffic).
    Accumulation is carried in f32 output storage.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    block_m = min(block_m, m)
    block_k = min(block_k, k)
    assert m % block_m == 0 and k % block_k == 0
    nm, nk = m // block_m, k // block_k

    out = pl.pallas_call(
        functools.partial(_dispersed_kernel, nk=nk),
        grid=(nk, nm),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda ik, im: (im, ik)),
            pl.BlockSpec((block_k, n), lambda ik, im: (ik, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda ik, im: (im, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out.astype(a.dtype)


def hbm_traffic_model(m: int, n: int, k: int, *, block_m: int, block_k: int,
                      working_set: int, bytes_per_el: int = 2) -> dict:
    """Closed-form HBM bytes for the two schedules (roofline input).

    grouped: A once, B once per group (=nm/W), C once.
    dispersed: A once, B once per k-step... (B reused across m at fixed k),
               C spilled+filled per k step.
    """
    nm = m // block_m
    nk = k // block_k
    w = min(working_set, nm)
    groups = max(nm // w, 1)
    grouped = (m * k + groups * k * n + m * n) * bytes_per_el
    dispersed = (m * k + nk * k * n // nk + 2 * m * n * nk) * bytes_per_el
    ideal = (m * k + k * n + m * n) * bytes_per_el
    return dict(grouped=grouped, dispersed=dispersed, ideal=ideal,
                vmem_acc_bytes=w * block_m * n * 4)
