"""Fused RMSNorm Pallas TPU kernel.

Memory-bound elementwise+reduction op: fusing the variance reduction with
the scale keeps the activation in VMEM for a single HBM round trip (vs two
for the naive two-pass form).  BlockSpec: (block_rows, d) row tiles —
d stays whole so the reduction is local to the block (one grid dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 128,
            interpret: bool = False):
    """x: (..., d); scale: (d,). Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    assert rows % br == 0
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
