"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` layer)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = False,
                  scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q,k,v: (B, H, S, D) with equal head counts."""
    *_, sq, d = q.shape
    sk = k.shape[-2]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def matmul_ref(a, b) -> jnp.ndarray:
    """C = A @ B in f32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)
                      ).astype(a.dtype)
