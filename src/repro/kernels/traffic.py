"""Instrumented HBM traffic counting for Pallas schedules.

The closed-form ``hbm_traffic_model`` in :mod:`repro.kernels.dispersed_gemm`
is the paper's economics; this module is the *measurement* side of the
roofline's model check: it walks a schedule's grid in Pallas TPU iteration
order (row-major, last dimension fastest) and counts the HBM block
transfers the pipeline actually issues, using the **same index-map
lambdas** the ``pallas_call`` is built from.  A disagreement between this
count and the closed form means one of them mis-states the schedule — the
exact class of bug that let the dispersed-B term go dead.

Counting semantics per :class:`Part` kind (documented because they ARE the
measurement definition):

  * ``"in"`` — an input block is fetched once per *run* of consecutive
    grid steps mapping to the same block index (Pallas keeps a block
    resident while its index is unchanged and refetches when it changes
    back later).
  * ``"out"`` — a pure output block is written exactly once (the final
    writeback; intermediate pipeline copies of unchanged buffers carry no
    model-relevant data and the closed form ignores them).
  * ``"acc"`` — an HBM-resident accumulator (the dispersed schedule's
    output tile) is *filled and spilled* once per run: every revisit
    round-trips, which is precisely the paper's spill/fill traffic at
    VMEM granularity.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

KINDS = ("in", "out", "acc")


@dataclasses.dataclass(frozen=True)
class Part:
    """One HBM-backed operand of a schedule: a block size in bytes, the
    BlockSpec index map, and the counting kind (see module docstring)."""

    name: str
    block_bytes: int
    index_map: Callable
    kind: str

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"part {self.name!r}: kind must be one of {KINDS}, "
                f"got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A schedule's traffic geometry: the grid plus its operand parts."""

    grid: tuple[int, ...]
    parts: tuple[Part, ...]

    def steps(self) -> int:
        return int(np.prod(self.grid))


def count(schedule: Schedule) -> dict[str, int]:
    """Walk the grid and count bytes moved per part (+ ``"total"``).

    The walk order is row-major with the last grid dimension fastest —
    Pallas TPU's sequential iteration order, which is what makes
    "consecutive steps with an unchanged block index" well defined.
    """
    runs = {p.name: 0 for p in schedule.parts}
    seen: dict[str, set] = {p.name: set() for p in schedule.parts}
    prev: dict[str, object] = {p.name: None for p in schedule.parts}
    for idx in np.ndindex(*schedule.grid):
        for p in schedule.parts:
            block = p.index_map(*idx)
            if block != prev[p.name]:
                runs[p.name] += 1
                prev[p.name] = block
                seen[p.name].add(block)
    out = {}
    for p in schedule.parts:
        if p.kind == "in":
            out[p.name] = runs[p.name] * p.block_bytes
        elif p.kind == "out":
            out[p.name] = len(seen[p.name]) * p.block_bytes
        else:                                   # "acc": fill + spill per run
            out[p.name] = 2 * runs[p.name] * p.block_bytes
    out["total"] = sum(out.values())
    return out
