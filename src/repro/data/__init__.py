from repro.data.pipeline import DataConfig, SyntheticCorpus
__all__ = ["DataConfig", "SyntheticCorpus"]
