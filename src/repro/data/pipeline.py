"""Deterministic, shard-aware synthetic data pipeline.

Generates a reproducible token stream (mixture of Zipfian unigram draws and
repeated n-gram 'motifs' so models have learnable structure) and serves
fixed-shape batches.  Every batch is a pure function of (seed, step, shard),
which gives exactly-once semantics across restarts and elastic re-sharding:
a restarted worker re-derives the batches it owes without coordination —
the data-side half of fault tolerance.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    motif_len: int = 16
    num_motifs: int = 64
    motif_prob: float = 0.35


class SyntheticCorpus:
    """Stateless batch source: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        g = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram distribution + a bank of repeated motifs.
        ranks = np.arange(1, v + 1)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = g.integers(0, v, (cfg.num_motifs, cfg.motif_len))

    def _sequence(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, np.int64)
        i = 0
        while i < out.size:
            if rng.random() < cfg.motif_prob:
                m = self._motifs[rng.integers(cfg.num_motifs)]
                n = min(m.size, out.size - i)
                out[i:i + n] = m[:n]
                i += n
            else:
                n = min(int(rng.integers(4, 32)), out.size - i)
                out[i:i + n] = rng.choice(
                    cfg.vocab_size, size=n, p=self._p)
                i += n
        return out

    def batch(self, step: int, *, shard: int = 0, num_shards: int = 1
              ) -> dict[str, np.ndarray]:
        """Global (or per-shard) batch for ``step``: tokens (B, S+1)."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        bsz = cfg.global_batch // num_shards
        rows = []
        for j in range(bsz):
            idx = step * cfg.global_batch + shard * bsz + j
            rng = np.random.default_rng((cfg.seed, idx))
            rows.append(self._sequence(rng))
        tokens = np.stack(rows).astype(np.int32)
        pos = np.broadcast_to(np.arange(cfg.seq_len, dtype=np.int32),
                              (bsz, cfg.seq_len))
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:],
                "positions": np.ascontiguousarray(pos)}
