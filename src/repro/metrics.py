"""Metrics as an API: a declarative registry of derived quantities over
labeled :class:`repro.api.SweepResult` grids.

Every headline number in the paper is a *derived* quantity, not a raw
counter — VRF/VPU/total area savings (Fig 2, §4.4.1), per-application
power (Fig 8), the equal-area narrow-VRF comparison (Fig 6), speedup over
the full VRF (Table 3).  This module makes those first-class: a
:class:`Metric` is a named, documented, composable function over the
counter grids of a ``SweepResult``, evaluated vectorized over the whole
grid at once.  Three kinds:

  * **derived** — pointwise counter algebra (``scaled_cycles``,
    ``spill_traffic_bytes``, ``l1_amat``);
  * **model** — the :mod:`repro.core.costmodel` area/power/energy models
    evaluated over the grid, with the ``capacity`` axis as the register
    count and machine axes as latencies (``total_area``,
    ``application_power``, ``energy``, ``edp``, ``narrow_vrf_cycles``);
  * **relational** — quantities *relative to a baseline point* of the same
    sweep (``speedup``, ``savings_pct``, ``ratio``, ``delta``): they take
    an explicit ``baseline=`` axis selection and broadcast the baseline
    slice against the full grid (on a zipped ``config`` axis the unpinned
    fields are matched per point).

The registry is the extension point: :func:`register` adds a new metric —
a custom hardware model needs no core edits (see ``docs/metrics.md``).
Consumers go through ``SweepResult.derive(metric, baseline=..., **params)``
/ ``normalize`` / ``pareto``, which evaluate here; metric functions may
request other metrics via ``ctx.counter`` and compose (``scalar_speedup``
= ``scalar_cycles`` / ``scaled_cycles``).  Evaluation is pure numpy on
counters the sweep already produced — deriving never triggers another
engine compile or dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.api import _CONFIG_FIELDS, _GEOMETRY_FIELDS
from repro.core import costmodel, isa

__all__ = [
    "Metric", "MetricContext", "register", "unregister", "get", "names",
    "evaluate", "area_headline", "KINDS",
]

KINDS = ("derived", "model", "relational")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One registered metric: a named, documented function over a labeled
    counter grid.  ``fn(ctx)`` for derived/model kinds, ``fn(ctx, base)``
    for relational ones (``base`` is the baseline-aligned view).
    ``params`` names the keyword parameters the metric accepts —
    ``evaluate`` rejects unknown ones; ``None`` skips the check (for
    free-form custom metrics)."""

    name: str
    kind: str
    doc: str
    fn: Callable
    params: tuple | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"metric kind must be one of {KINDS}, got {self.kind!r}")


_REGISTRY: dict[str, Metric] = {}

_PLUGINS_LOADED = False


def _load_plugins() -> None:
    """Import the metric-registering plugin packages exactly once.

    ``repro.silicon`` registers its macro-calibrated metrics
    (``silicon_area``, ``silicon_energy``, ...) through :func:`register`
    at import time — the no-core-edit extension path.  The import is lazy
    (first unknown-name lookup or catalog dump) so ``repro.metrics``
    itself stays importable from the core without the plugin layers."""
    global _PLUGINS_LOADED
    if _PLUGINS_LOADED:
        return
    _PLUGINS_LOADED = True
    import repro.silicon  # noqa: F401  (registers its metrics)


def register(name: str, kind: str, doc: str = "", override: bool = False,
             params: tuple | None = None):
    """Decorator registering a metric function under ``name``.

    ``kind`` is ``"derived"`` / ``"model"`` / ``"relational"``; ``doc``
    is the one-line description surfaced in ``run.py --json`` metadata;
    ``params`` names the accepted keyword parameters (unknown ones are
    rejected at evaluation; ``None`` — the default for custom metrics —
    accepts anything).  Re-registering an existing name raises unless
    ``override=True``.
    """
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and not override:
            raise ValueError(f"metric {name!r} registered twice "
                             "(pass override=True to replace)")
        _REGISTRY[name] = Metric(name, kind, doc or (fn.__doc__ or ""), fn,
                                 tuple(params) if params is not None
                                 else None)
        return fn
    return deco


def unregister(name: str) -> None:
    """Remove a registered metric (tests and notebook experimentation)."""
    _REGISTRY.pop(name, None)


def get(metric) -> Metric:
    """Registry lookup; unknown names raise with the sorted menu."""
    if isinstance(metric, Metric):
        return metric
    try:
        return _REGISTRY[metric]
    except KeyError:
        _load_plugins()
        try:
            return _REGISTRY[metric]
        except KeyError:
            raise KeyError(
                f"unknown metric {metric!r}; registered: "
                f"{', '.join(sorted(_REGISTRY))}") from None


def names() -> list[str]:
    """Sorted names of every registered metric."""
    _load_plugins()
    return sorted(_REGISTRY)


def catalog() -> dict[str, dict]:
    """JSON-safe registry dump: name -> {kind, doc} (for ``run.py --json``)."""
    _load_plugins()
    return {n: dict(kind=m.kind, doc=m.doc.strip().splitlines()[0]
                    if m.doc.strip() else "")
            for n, m in sorted(_REGISTRY.items())}


# ---------------------------------------------------------------------------
# Evaluation context.
# ---------------------------------------------------------------------------


class MetricContext:
    """What a metric function sees: the grid's counters, the axis values
    broadcast as grids, and the call's parameters.

    ``counter(name)`` returns the named counter array — or, when ``name``
    is itself a registered derived/model metric not yet in the data,
    evaluates it on demand so metrics compose.  The call's parameters
    propagate down the composition chain (``derive("energy", pp=...)``
    reaches ``application_power``); only parameter-free evaluations are
    cached into the result (a parameterised sub-metric under its
    canonical name would poison later reads).
    """

    def __init__(self, result, params: dict | None = None, _stack=()):
        self.result = result
        self.params = dict(params or {})
        self._stack = _stack

    @property
    def shape(self) -> tuple[int, ...]:
        return self.result.shape

    def counter(self, name: str) -> np.ndarray:
        data = self.result.data
        if name in data:
            return data[name]
        if name in _REGISTRY:
            if name in self._stack:
                raise ValueError(
                    f"metric dependency cycle: {' -> '.join(self._stack)}"
                    f" -> {name}")
            m = _REGISTRY[name]
            if m.kind == "relational":
                raise ValueError(
                    f"metric {name!r} is relational — derive it explicitly "
                    "with a baseline= selection first")
            sub = MetricContext(self.result, self.params,
                                self._stack + (name,))
            arr = np.broadcast_to(
                np.asarray(m.fn(sub)), self.shape).copy()
            if not self.params:
                data[name] = arr
            return arr
        raise KeyError(
            f"no counter or registered metric {name!r}; counters: "
            f"{sorted(data)}")

    def axis_values(self, name: str) -> tuple:
        return self.result.axis(name).values

    def axis_grid(self, name: str) -> np.ndarray:
        """The per-point values of one axis (or config/geometry field),
        shaped to broadcast against the counter grids."""
        axes = self.result.axes
        axis_names = [a.name for a in axes]
        if name in axis_names:
            ai = axis_names.index(name)
            vals = list(axes[ai].values)
        elif name in _CONFIG_FIELDS and "config" in axis_names:
            ai = axis_names.index("config")
            vals = [getattr(c, name) for c in axes[ai].values]
        elif name in _GEOMETRY_FIELDS and "l1_geometry" in axis_names:
            ai = axis_names.index("l1_geometry")
            vals = [getattr(g, _GEOMETRY_FIELDS[name])
                    for g in axes[ai].values]
        else:
            raise KeyError(
                f"no axis or axis field {name!r}; axes: {axis_names}")
        arr = np.asarray(vals)
        shape = [1] * len(axes)
        shape[ai] = len(vals)
        return arr.reshape(shape)

    @property
    def kernel_params(self):
        """The sweep's build-size selector (``"paper"``/``"reduced"``/dict)."""
        return self.result.meta.get("kernel_params", "paper")


# ---------------------------------------------------------------------------
# Evaluation entry point (SweepResult.derive lands here).
# ---------------------------------------------------------------------------


def evaluate(result, metric, baseline: dict | None = None,
             params: dict | None = None) -> np.ndarray:
    """Evaluate one metric over a labeled result grid, returning an array
    broadcastable to the grid's shape.  Relational metrics require
    ``baseline`` (an axis-selection dict, see
    ``SweepResult._baseline_view``); other kinds forbid it.  On-demand
    sub-metrics requested via ``ctx.counter`` are cached into
    ``result.data`` as a side effect.
    """
    m = get(metric)
    if m.params is not None and params:
        unknown = sorted(set(params) - set(m.params))
        if unknown:
            raise TypeError(
                f"metric {m.name!r} got unknown parameter(s) "
                f"{', '.join(unknown)}; accepts: "
                f"{', '.join(m.params) or '(none)'}")
    ctx = MetricContext(result, params, (m.name,))
    if m.kind == "relational":
        if baseline is None:
            raise ValueError(
                f"metric {m.name!r} is relational; pass baseline= "
                "(e.g. baseline=dict(capacity=32))")
        base = MetricContext(result._baseline_view(baseline), params,
                             (m.name,))
        return np.asarray(m.fn(ctx, base))
    if baseline is not None:
        raise ValueError(
            f"metric {m.name!r} is {m.kind}, not relational — baseline= "
            "does not apply")
    return np.asarray(m.fn(ctx))


# ---------------------------------------------------------------------------
# Built-in derived metrics: pointwise counter algebra.
# ---------------------------------------------------------------------------


@register("scaled_cycles", "derived",
          "cycles corrected for prefix truncation (cycles * event_scale; "
          "equal to cycles on folded/full runs)",
          params=())
def _scaled_cycles(ctx):
    return ctx.counter("cycles") * ctx.counter("event_scale")


@register("spill_traffic_bytes", "derived",
          "bytes moved by dispersion spill/fill traffic "
          "((spills + fills) * VLEN_BYTES)",
          params=())
def _spill_traffic(ctx):
    return (ctx.counter("spills") + ctx.counter("fills")) * isa.VLEN_BYTES


@register("l1_amat", "derived",
          "L1 average memory access time: (1 + l1_hit_cycles) + "
          "miss_rate * mem_latency, from the sweep's machine axes",
          params=())
def _l1_amat(ctx):
    hits = ctx.counter("l1_hits")
    misses = ctx.counter("l1_misses")
    acc = hits + misses
    with np.errstate(divide="ignore", invalid="ignore"):
        miss_rate = np.where(acc > 0, misses / np.maximum(acc, 1), 0.0)
    return (1.0 + ctx.axis_grid("l1_hit_cycles")
            + miss_rate * ctx.axis_grid("mem_latency"))


@register("arithmetic_intensity", "derived",
          "flops per instrumented HBM byte (flops / counted_bytes) — the "
          "measured x-coordinate of a roofline point",
          params=())
def _arithmetic_intensity(ctx):
    return ctx.counter("flops") / ctx.counter("counted_bytes")


@register("model_arithmetic_intensity", "derived",
          "flops per closed-form hbm_traffic_model byte "
          "(flops / model_bytes) — the model x-coordinate of a "
          "roofline point",
          params=())
def _model_arithmetic_intensity(ctx):
    return ctx.counter("flops") / ctx.counter("model_bytes")


@register("achieved_gflops", "derived",
          "measured compute throughput (flops / us_per_call / 1e3) — the "
          "y-coordinate of a roofline point",
          params=())
def _achieved_gflops(ctx):
    return ctx.counter("flops") / ctx.counter("us_per_call") / 1e3


# ---------------------------------------------------------------------------
# Built-in model metrics: vectorized costmodel over the grid.
# ---------------------------------------------------------------------------


def _dispersed_grid(ctx):
    """(n_vregs, dispersed) grids for the cost models.  ``dispersed``
    defaults to "auto": any capacity below the architectural register
    count runs the dispersion mechanism (matches every paper study)."""
    cap = ctx.axis_grid("capacity")
    d = ctx.params.get("dispersed", "auto")
    if isinstance(d, str) and d == "auto":
        return cap, cap < isa.NUM_ARCH_VREGS
    return cap, np.broadcast_to(np.asarray(bool(d)), cap.shape)


def _area_component(ctx, key):
    cap, disp = _dispersed_grid(ctx)
    grids = costmodel.cpu_area_grid(
        cap, n_lanes=ctx.params.get("n_lanes", 8), dispersed=disp)
    return grids[key]


@register("vrf_area", "model",
          "cVRF register+routing area (au) at each point's capacity "
          "(costmodel.cpu_area_grid; dispersed='auto' below 32 regs)",
          params=("dispersed", "n_lanes"))
def _vrf_area(ctx):
    return _area_component(ctx, "vrf")


@register("vpu_area", "model",
          "whole-VPU area (au): VRF + coupling + ALU + dispersion overhead",
          params=("dispersed", "n_lanes"))
def _vpu_area(ctx):
    return _area_component(ctx, "vpu")


@register("total_area", "model",
          "CPU+VPU logic area (au), excluding L1 SRAM macros (as Fig 7)",
          params=("dispersed", "n_lanes"))
def _total_area(ctx):
    return _area_component(ctx, "total")


@register("area_with_l1", "model",
          "total_area plus the L1 data-cache SRAM macro from the sweep's "
          "l1_geometry axis — the Pareto-frontier area axis; macro_model "
          "selects a repro.silicon backend (None = legacy constants, "
          "which the 'flop' backend reproduces bit-identically)",
          params=("dispersed", "n_lanes", "macro_model"))
def _area_with_l1(ctx):
    sram = costmodel.l1_sram_area(ctx.axis_grid("l1_sets"),
                                  ctx.axis_grid("l1_ways"),
                                  macro=ctx.params.get("macro_model"))
    return ctx.counter("total_area") + sram


@register("application_power", "model",
          "average application power (model units) from activity counters "
          "at each point's capacity (costmodel.application_power_grid)",
          params=("dispersed", "n_lanes", "pp"))
def _application_power(ctx):
    cap, disp = _dispersed_grid(ctx)
    return costmodel.application_power_grid(
        ctx.result.data, cap, n_lanes=ctx.params.get("n_lanes", 8),
        dispersed=disp, pp=ctx.params.get("pp", costmodel.DEFAULT_POWER),
    )["total"]


@register("energy", "model",
          "application energy (model units): application_power * "
          "scaled_cycles",
          params=("dispersed", "n_lanes", "pp"))
def _energy(ctx):
    return ctx.counter("application_power") * ctx.counter("scaled_cycles")


@register("edp", "model",
          "energy-delay product: energy * scaled_cycles",
          params=("dispersed", "n_lanes", "pp"))
def _edp(ctx):
    return ctx.counter("energy") * ctx.counter("scaled_cycles")


@register("scalar_cycles", "model",
          "analytic scalar-core cycles per kernel (ScalarCost at the "
          "sweep's build size and mem_latency axis) — Table 3's baseline",
          params=())
def _scalar_cycles(ctx):
    from repro import rvv  # runtime import: kernels sit above the core
    kernels = ctx.axis_values("kernel")
    mems = ctx.axis_values("mem_latency")
    kp = ctx.kernel_params
    table = np.empty((len(kernels), len(mems)), np.float64)
    for ki, name in enumerate(kernels):
        bench = rvv.get_benchmark(name)
        kw = dict(bench.paper_params if kp == "paper"
                  else bench.reduced_params if kp == "reduced" else kp)
        sc = bench.scalar_cost(**kw)
        for mi, mem in enumerate(mems):
            from repro.core.simulator import MachineParams
            table[ki, mi] = sc.cycles(MachineParams(mem_latency=int(mem)))
    axes = [a.name for a in ctx.result.axes]
    shape = [1] * len(axes)
    shape[axes.index("kernel")] = len(kernels)
    shape[axes.index("mem_latency")] = len(mems)
    return table.reshape(shape)


@register("scalar_speedup", "derived",
          "vector speedup over the analytic scalar core: scalar_cycles / "
          "scaled_cycles (Table 3)",
          params=())
def _scalar_speedup(ctx):
    return ctx.counter("scalar_cycles") / ctx.counter("scaled_cycles")


@register("narrow_vrf_cycles", "model",
          "Fig 6 equal-area narrow machine: cycles of a full-VRF core at "
          "VL/strip_factor, modelled from this point's counters and the "
          "sweep's machine axes (L1 access = 1 + l1_hit_cycles, miss adds "
          "mem_latency)",
          params=("strip_factor",))
def _narrow_vrf_cycles(ctx):
    """With VL/strip, every vector instruction strip-mines into ``strip``
    (strip x base occupancy and loop overhead) while each 32-byte line is
    touched by ``strip`` narrow accesses (1 miss + strip-1 extra hits per
    previously-missed line); the narrow VRF holds all 32 registers so it
    has no dispersion stalls."""
    strip = float(ctx.params.get("strip_factor", 4))
    hit_cost = 1.0 + ctx.axis_grid("l1_hit_cycles")
    miss_cost = hit_cost + ctx.axis_grid("mem_latency")
    l1_hits = np.asarray(ctx.counter("l1_hits"), np.float64)
    l1_miss = np.asarray(ctx.counter("l1_misses"), np.float64)
    mem_cycles = l1_hits * hit_cost + l1_miss * miss_cost
    compute_cycles = np.asarray(ctx.counter("cycles"), np.float64) \
        - mem_cycles
    naccess = (l1_hits + l1_miss) * strip
    return (strip * compute_cycles + (naccess - l1_miss) * hit_cost
            + l1_miss * miss_cost)


@register("narrow_vrf_speedup", "derived",
          "full-VRF cycles over the equal-area narrow machine's cycles at "
          "the same point (Fig 6's narrow_32x64 column)",
          params=("strip_factor",))
def _narrow_vrf_speedup(ctx):
    return ctx.counter("cycles") / ctx.counter("narrow_vrf_cycles")


# ---------------------------------------------------------------------------
# Cluster metrics: over grids with a ``cores`` axis (repro.cluster sweeps).
# The shared-memory system (L2 geometry, channels) is uniform across the
# grid and rides on ``meta["cluster"]``; per-core quantities come from the
# existing axes (capacity, l1_geometry) times the ``cores`` axis.
# ---------------------------------------------------------------------------


def _cluster_meta(ctx) -> dict:
    cl = ctx.result.meta.get("cluster")
    if cl is None:
        raise KeyError(
            "no meta['cluster'] — this metric needs a cluster sweep "
            "(api.Sweep with a cores axis, run through Session.run)")
    return cl


@register("cluster_area", "model",
          "whole-cluster area (au): cores * (CPU+VPU logic + L1 macro) "
          "plus the shared-L2 SRAM macro from meta['cluster']; "
          "macro_model prices both macros through a repro.silicon "
          "backend (None = legacy constants)",
          params=("dispersed", "n_lanes", "macro_model"))
def _cluster_area(ctx):
    cl = _cluster_meta(ctx)
    macro = ctx.params.get("macro_model")
    if macro is not None:
        from repro import silicon  # lazy: plugin layer above the core
        model = silicon.get_macro_model(macro)
        l2_au = float(model.area(cl["l2_sets"] * cl["l2_ways"], 32 * 8)) \
            if cl["l2_bytes"] else 0.0
    else:
        l2_au = cl["l2_bytes"] * 8 * costmodel.SRAM_AU_PER_BIT \
            + (costmodel.SRAM_PERIPHERY_AU if cl["l2_bytes"] else 0.0)
    return ctx.axis_grid("cores") * ctx.counter("area_with_l1") + l2_au


@register("sram_budget_bytes", "model",
          "total storage the cluster holds: cores * (capacity * VLEN_BYTES "
          "+ L1 bytes) + shared-L2 bytes — the iso-budget axis of "
          "benchmarks/cluster_sweep.py",
          params=())
def _sram_budget_bytes(ctx):
    cl = _cluster_meta(ctx)
    l1_bytes = ctx.axis_grid("l1_sets") * ctx.axis_grid("l1_ways") * 32
    per_core = ctx.axis_grid("capacity") * isa.VLEN_BYTES + l1_bytes
    return ctx.axis_grid("cores") * per_core + cl["l2_bytes"]


@register("aggregate_throughput", "derived",
          "cluster-wide useful work rate: summed reg_writes per makespan "
          "cycle (reg_writes / scaled_cycles) — N perfectly scaling cores "
          "read N x the single-core value",
          params=())
def _aggregate_throughput(ctx):
    return ctx.counter("reg_writes") / ctx.counter("scaled_cycles")


@register("contention_stall_ratio", "derived",
          "fraction of total core-cycles spent queued on the shared "
          "memory channels (contention_stalls / core_cycles_sum); 0 on a "
          "passthrough or single-core cluster",
          params=())
def _contention_stall_ratio(ctx):
    stalls = np.asarray(ctx.counter("contention_stalls"), np.float64)
    total = np.asarray(ctx.counter("core_cycles_sum"), np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(total > 0, stalls / np.maximum(total, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Built-in relational metrics: baseline-relative queries.
# ---------------------------------------------------------------------------


@register("speedup", "relational",
          "baseline cycles over this point's cycles (scaled_cycles, so "
          "truncated prefixes compare fairly); 1.0 at the baseline",
          params=())
def _speedup(ctx, base):
    return base.counter("scaled_cycles") / ctx.counter("scaled_cycles")


@register("ratio", "relational",
          "of= counter/metric at this point over its baseline value",
          params=("of",))
def _ratio(ctx, base):
    of = ctx.params["of"]
    return ctx.counter(of) / base.counter(of)


@register("savings_pct", "relational",
          "percent reduction of of= relative to the baseline: "
          "100 * (1 - x / x_baseline)",
          params=("of",))
def _savings_pct(ctx, base):
    of = ctx.params["of"]
    return 100.0 * (1.0 - ctx.counter(of) / base.counter(of))


@register("delta", "relational",
          "of= at this point minus its baseline value",
          params=("of",))
def _delta(ctx, base):
    of = ctx.params["of"]
    return ctx.counter(of) - base.counter(of)


@register("equal_area_advantage", "relational",
          "Fig 6 verdict: the equal-area narrow machine's cycles (from the "
          "baseline's counters) over this point's cycles — >1 means "
          "dispersion beats narrowing at equal area",
          params=("strip_factor",))
def _equal_area_advantage(ctx, base):
    return base.counter("narrow_vrf_cycles") / ctx.counter("cycles")


# ---------------------------------------------------------------------------
# Serving SLO metrics: over grids built with SweepResult.from_table from
# repro.serve.slo.SLOReport rows (the serving_slo benchmark).
# ---------------------------------------------------------------------------


@register("slo_attainment", "derived",
          "fraction of admission attempts meeting their deadline: "
          "1 - deadline_miss_rate",
          params=())
def _slo_attainment(ctx):
    return 1.0 - ctx.counter("deadline_miss_rate")


@register("goodput", "derived",
          "SLO-weighted throughput: tokens_per_tick * slo_attainment "
          "(tokens that arrived in time, per virtual tick)",
          params=())
def _goodput(ctx):
    return ctx.counter("tokens_per_tick") * ctx.counter("slo_attainment")


@register("degraded_throughput_ratio", "derived",
          "throughput under active faults over overall throughput "
          "(degraded_tokens_per_tick / tokens_per_tick); ~1.0 means "
          "degradation was graceful, 0 means service stopped",
          params=())
def _degraded_throughput_ratio(ctx):
    tps = np.asarray(ctx.counter("tokens_per_tick"), np.float64)
    deg = np.asarray(ctx.counter("degraded_tokens_per_tick"), np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(tps > 0, deg / np.maximum(tps, 1e-12), 0.0)


# ---------------------------------------------------------------------------
# Standalone model queries (no sweep needed).
# ---------------------------------------------------------------------------


def area_headline(n_full: int = isa.NUM_ARCH_VREGS,
                  n_cvrf: int = 8) -> dict:
    """The Fig 2 / §4.4.1 headline rows as one model query: baseline
    breakdown percentages plus the three savings predictions (paper:
    61% / 43.4% / 3.5x / 53% / 23%)."""
    full = costmodel.cpu_area(n_full, dispersed=False)
    cvrf = costmodel.cpu_area(n_cvrf, dispersed=True)
    return dict(
        baseline_vrf_pct_of_vpu=100 * full.vrf / full.vpu,
        baseline_vpu_pct_of_total=100 * full.vpu / full.total,
        vrf_area_reduction_x=full.vrf / (cvrf.vrf
                                         + cvrf.dispersion_overhead),
        vpu_area_saving_pct=100 * (1 - cvrf.vpu / full.vpu),
        total_area_saving_pct=100 * (1 - cvrf.total / full.total),
    )
