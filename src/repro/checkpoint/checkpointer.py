"""Sharded checkpointing with restore and elastic re-sharding.

Format: one ``.npz`` per host-shard (here: per process) + a JSON manifest
with the pytree structure, step, and mesh shape.  Saves run in a background
thread (async) double-buffered so the train loop never blocks on IO; the
manifest is written last and atomically, so a crash mid-save never corrupts
the previous checkpoint (restart reads the newest *complete* manifest).

Elastic re-sharding: arrays are stored unsharded-per-leaf (this container is
single-process); on restore under a *different* mesh the launcher re-applies
its sharding rules, so scaling from N to M pods between runs is a restore +
re-jit — no format change.  On a multi-host cluster the same layout holds
per-host with ``jax.experimental.multihost_utils`` gathers (single-process
fallback used here).
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state: dict, blocking: bool = False) -> None:
        """Async save of a pytree-of-arrays ``state`` at ``step``."""
        self.wait()
        # Snapshot to host memory synchronously (cheap vs IO), write async.
        flat, _ = _flatten_with_paths(state)
        # npz cannot serialise ml_dtypes (bf16); store as f32 (lossless up).
        host = {}
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                               np.int32, np.int16, np.int8, np.uint8,
                               np.bool_):
                a = a.astype(np.float32)
            host[k] = a

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(path, exist_ok=True)
            np.savez(os.path.join(path, "shard_0.npz"), **host)
            manifest = {"step": step, "time": time.time(),
                        "keys": sorted(host.keys())}
            tmp = os.path.join(path, "manifest.json.tmp")
            with open(tmp, "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, os.path.join(path, "manifest.json"))
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            path = os.path.join(self.dir, f"step_{s:08d}")
            for f in os.listdir(path):
                os.remove(os.path.join(path, f))
            os.rmdir(path)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        steps = []
        for d in os.listdir(self.dir):
            if (d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json"))):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: dict, step: int | None = None,
                shard_fn=None) -> tuple[int, dict]:
        """Restore into the structure of ``like``; ``shard_fn(path, arr)``
        (optional) re-shards each leaf for the current mesh (elastic)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "shard_0.npz"))
        flat, treedef = _flatten_with_paths(like)
        restored = {}
        for key, leaf in flat.items():
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            restored[key] = shard_fn(key, arr) if shard_fn else arr
        leaves = [restored[k] for k in flat.keys()]
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
