"""Fault injection for the serving engine: latency spikes, transient slot
failures, and live memory-pressure events — all on the virtual clock, all
seeded, so a chaotic run is exactly replayable and comparable against its
fault-free twin.

The injector does not bypass the engine's control plane; it *drives* it:

  * ``latency_spike``  — multiplies the virtual duration of every decode
    step in its window (a slow accelerator / noisy neighbour); global
    spikes shift the whole latency distribution but trip no eviction,
    because :class:`repro.runtime.fault_tolerance.StragglerPolicy` is
    median-based.
  * ``slot_fail``      — freezes one batching slot for a window: the slot
    stops making progress (the engine rolls its cache slice back each
    step, so no state corruption), its heartbeat step-time inflates, and
    the engine's straggler policy accumulates strikes until it *evicts*
    the slot — preempting the victim request (KV spilled to cold) and
    re-admitting it later, bit-identically.
  * ``mem_pressure``   — shrinks the hot KV pool live
    (:meth:`DispersedKVPool.shrink`): policy-selected victims are force-
    spilled and service continues from the smaller pool — the paper's
    graceful-degradation bet, measured while it happens.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FaultEvent", "FaultProfile", "FaultInjector", "make_profile",
           "FAULT_PROFILES", "KINDS"]

KINDS = ("latency_spike", "slot_fail", "mem_pressure")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``t`` is virtual ticks; meaning of the rest
    depends on ``kind``:

      latency_spike: ``magnitude`` x step duration for ``duration`` ticks
      slot_fail:     slot ``slot`` frozen for ``duration`` ticks
      mem_pressure:  hot pool shrunk to ``magnitude`` pages (int)
    """

    t: float
    kind: str
    duration: float = 0.0
    magnitude: float = 1.0
    slot: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got "
                             f"{self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """A named, immutable fault schedule (events sorted by time)."""

    name: str
    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events", tuple(sorted(self.events, key=lambda e: e.t)))


def make_profile(name: str, *, seed: int = 0, horizon: float = 200.0,
                 slots: int = 4, spike_rate: float = 0.0,
                 spike_magnitude: float = 4.0, spike_duration: float = 3.0,
                 n_slot_fails: int = 0, fail_duration: float = 8.0,
                 shrink_at_frac: float | None = None,
                 shrink_to: int = 0) -> FaultProfile:
    """Seeded schedule generator.  ``spike_rate`` is spikes per tick
    (Poisson); slot failures and the (single) shrink are placed uniformly /
    at ``shrink_at_frac * horizon``."""
    rng = np.random.default_rng(seed)
    events: list[FaultEvent] = []
    if spike_rate > 0:
        t = float(rng.exponential(1.0 / spike_rate))
        while t < horizon:
            events.append(FaultEvent(t=t, kind="latency_spike",
                                     duration=spike_duration,
                                     magnitude=spike_magnitude))
            t += float(rng.exponential(1.0 / spike_rate))
    for _ in range(n_slot_fails):
        events.append(FaultEvent(
            t=float(rng.uniform(0.1 * horizon, 0.8 * horizon)),
            kind="slot_fail", duration=fail_duration,
            slot=int(rng.integers(0, slots))))
    if shrink_at_frac is not None:
        events.append(FaultEvent(t=float(shrink_at_frac * horizon),
                                 kind="mem_pressure",
                                 magnitude=int(shrink_to)))
    return FaultProfile(name=name, events=tuple(events))


class FaultInjector:
    """Replays a :class:`FaultProfile` against a ``ServeEngine``.

    The engine calls :meth:`apply` once per step (before decoding) with
    itself and the current virtual time; due events mutate the engine
    through its public fault surface (``fail_slot`` / ``shrink_pool``) or
    this injector's spike window, which the engine reads via
    :meth:`latency_multiplier`.
    """

    def __init__(self, profile: FaultProfile):
        self.profile = profile
        self._next = 0
        self._spike_until = -1.0
        self._spike_mult = 1.0
        self.applied: list[FaultEvent] = []

    def reset(self) -> None:
        self._next = 0
        self._spike_until = -1.0
        self._spike_mult = 1.0
        self.applied = []

    def latency_multiplier(self, now: float) -> float:
        return self._spike_mult if now < self._spike_until else 1.0

    def fault_active(self, now: float) -> bool:
        """Whether any injected fault window covers ``now`` (the flag SLO
        accounting uses for degraded-mode throughput)."""
        return now < self._spike_until or bool(self.applied) and any(
            e.kind != "latency_spike" and e.t <= now < e.t + max(
                e.duration, 1.0)
            for e in self.applied)

    def apply(self, engine, now: float) -> list[FaultEvent]:
        """Fire every event with ``t <= now``; returns the fired events."""
        fired = []
        evs = self.profile.events
        while self._next < len(evs) and evs[self._next].t <= now:
            e = evs[self._next]
            self._next += 1
            if e.kind == "latency_spike":
                # overlapping spikes extend the window, max magnitude wins
                self._spike_mult = max(
                    self._spike_mult if now < self._spike_until else 1.0,
                    e.magnitude)
                self._spike_until = max(self._spike_until,
                                        now + e.duration)
            elif e.kind == "slot_fail":
                engine.fail_slot(e.slot % engine.slots,
                                 until=now + e.duration)
            elif e.kind == "mem_pressure":
                engine.shrink_pool(int(e.magnitude))
            self.applied.append(e)
            fired.append(e)
        return fired


# Named profiles the SLO benchmark sweeps over.  They are *factories* over
# (horizon, slots, hot pages) because a schedule only means something
# relative to the scenario it fires into.
def _none(horizon, slots, hot_pages, seed=0):
    del horizon, slots, hot_pages, seed
    return FaultProfile(name="none")


def _spikes(horizon, slots, hot_pages, seed=0):
    del hot_pages
    return make_profile("spikes", seed=seed, horizon=horizon, slots=slots,
                        spike_rate=0.03, spike_magnitude=5.0,
                        spike_duration=4.0)


def _chaos(horizon, slots, hot_pages, seed=0):
    """The acceptance scenario: latency spikes + one forced hot-pool
    shrink + a transient slot failure."""
    base = make_profile("chaos", seed=seed, horizon=horizon, slots=slots,
                        spike_rate=0.02, spike_magnitude=4.0,
                        spike_duration=3.0, n_slot_fails=1,
                        fail_duration=10.0, shrink_at_frac=0.4,
                        shrink_to=max(hot_pages - hot_pages // 3,
                                      slots + 2))
    return base


FAULT_PROFILES = {"none": _none, "spikes": _spikes, "chaos": _chaos}
