"""SLO accounting over a finished serving run.

:func:`summarize` folds a completed ``ServeEngine.serve`` run (the request
list plus the engine's telemetry) into one :class:`SLOReport` row — the
unit the SLO benchmark sweeps over and the Pareto front is built from:

  * **latency** — per-token decode latency percentiles (p50/p99 of
    inter-token gaps, virtual ticks) and time-to-first-token;
  * **throughput** — tokens per tick overall, and separately during
    *degraded* windows (fault active / slot frozen / pool shrunk), so the
    "graceful" in graceful degradation is a number, not an adjective;
  * **SLO** — deadline-miss rate over admission attempts, plus terminal
    counts (done / failed / rejected / retries / preemptions);
  * **footprint** — hot-pool fast-memory bytes (dispersed mode) or the
    full resident cache size, the x-axis of the paper's economics.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.engine import DONE, FAILED, REJECTED

__all__ = ["SLOReport", "summarize"]


@dataclasses.dataclass(frozen=True)
class SLOReport:
    n_requests: int
    n_done: int
    n_failed: int
    n_rejected: int
    n_retries: int
    n_preemptions: int
    deadline_misses: int
    deadline_miss_rate: float
    tokens_out: int
    elapsed_ticks: float
    tokens_per_tick: float
    degraded_ticks: float
    degraded_tokens: int
    degraded_tokens_per_tick: float
    p50_decode_ticks: float
    p99_decode_ticks: float
    mean_ttft_ticks: float
    hot_bytes: int
    pool_hit_rate: float
    pool_spills: int
    pool_shrinks: int

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


def _percentile(values: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if values else 0.0


def summarize(engine, requests) -> SLOReport:
    """Fold one finished run into an :class:`SLOReport`."""
    gaps: list[float] = []          # inter-token decode latencies
    ttfts: list[float] = []         # admission -> first token
    tokens_out = 0
    for r in requests:
        tokens_out += len(r.out)
        if r.first_token_t is not None and r.admit_t is not None:
            ttfts.append(r.first_token_t - r.admit_t)
        ts = r.token_times
        gaps.extend(b - a for a, b in zip(ts, ts[1:]))

    log = engine.step_log
    elapsed = log[-1]["t"] if log else 0.0
    degraded_ticks = sum(row["dur"] for row in log if row["degraded"])
    degraded_tokens = sum(row["emitted"] for row in log if row["degraded"])

    n_done = sum(r.status == DONE for r in requests)
    n_failed = sum(r.status == FAILED for r in requests)
    n_rejected = sum(r.status == REJECTED for r in requests)
    # every deadline miss ends one admission attempt, as does each
    # terminal done/failed — the denominator of the miss rate
    attempts = n_done + n_failed + engine.deadline_misses
    stats = engine.kv_stats()
    if stats:
        hot_bytes = stats["hot_bytes"]
    else:
        hot_bytes = sum(int(np.prod(v.shape)) * v.dtype.itemsize
                        for v in engine.cache.values())
    return SLOReport(
        n_requests=len(requests),
        n_done=n_done,
        n_failed=n_failed,
        n_rejected=n_rejected,
        n_retries=sum(r.retries for r in requests),
        n_preemptions=sum(r.preemptions for r in requests),
        deadline_misses=engine.deadline_misses,
        deadline_miss_rate=engine.deadline_misses / max(attempts, 1),
        tokens_out=tokens_out,
        elapsed_ticks=float(elapsed),
        tokens_per_tick=tokens_out / max(elapsed, 1e-9),
        degraded_ticks=float(degraded_ticks),
        degraded_tokens=int(degraded_tokens),
        degraded_tokens_per_tick=degraded_tokens / max(degraded_ticks, 1e-9)
        if degraded_ticks else 0.0,
        p50_decode_ticks=_percentile(gaps, 50),
        p99_decode_ticks=_percentile(gaps, 99),
        mean_ttft_ticks=float(np.mean(ttfts)) if ttfts else 0.0,
        hot_bytes=int(hot_bytes),
        pool_hit_rate=float(stats.get("hit_rate", 1.0)) if stats else 1.0,
        pool_spills=int(stats.get("spills", 0)) if stats else 0,
        pool_shrinks=int(stats.get("shrinks", 0)) if stats else 0,
    )
