"""Batched serving engine: prefill + decode with continuous batching slots.

The engine drives ``Model.decode_step`` (jit'd once per shape) over a fixed
slot grid; finished requests free their slot for the next queued request
(continuous batching).  KV state lives either fully resident or behind the
DispersedKVPool (``kv_mode='dispersed'``) which bounds fast-memory use per
the paper's mechanism.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.cache = self.model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int64)
        self.active: list[Request | None] = [None] * slots
        self.pending_prefill: list[tuple[int, list[int]]] = []
        self._decode = jax.jit(self.model.decode_step)

    # ------------------------------------------------------------ intake --
    def _reset_slot(self, s: int) -> None:
        """Zero slot ``s`` across all cache tensors: recurrent state (SSM /
        RG-LRU) would otherwise leak from the previous occupant of the slot
        (KV entries are masked by positions, but states carry over)."""
        for k, v in self.cache.items():
            self.cache[k] = v.at[:, s].set(0)

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                self.active[s] = req
                self.pos[s] = 0
                self._reset_slot(s)
                self.pending_prefill.append((s, list(req.prompt)))
                return True
        return False

    # ------------------------------------------------------------- steps --
    def _batch(self, tokens_np, positions_np):
        b = {"tokens": jnp.asarray(tokens_np, jnp.int32),
             "positions": jnp.asarray(positions_np, jnp.int32)}
        if self.cfg.positional == "mrope":
            b["positions3"] = jnp.broadcast_to(
                b["positions"][None], (3,) + b["positions"].shape)
        if self.cfg.encoder_decoder:
            pass  # cross-KV prepared at submission time by the audio stub
        return b

    def step(self) -> list[tuple[Request, int]]:
        """One engine step: feed each active slot its next token (prompt
        token during prefill-by-decode, else the last sampled token)."""
        tokens = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            p = int(self.pos[s])
            if p < len(req.prompt):
                tokens[s, 0] = req.prompt[p]
            elif req.out:
                tokens[s, 0] = req.out[-1]
        positions = self.pos[:, None].astype(np.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, self._batch(tokens, positions))
        logits = np.asarray(logits[:, 0], np.float32)

        emitted = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            if self.pos[s] < len(req.prompt):
                continue                       # still consuming the prompt
            if self.temperature > 0:
                self.key, k = jax.random.split(self.key)
                tok = int(jax.random.categorical(
                    k, jnp.asarray(logits[s]) / self.temperature))
            else:
                tok = int(np.argmax(logits[s]))
            req.out.append(tok)
            emitted.append((req, tok))
            if (len(req.out) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
        return emitted

    def run(self, requests: list[Request], max_steps: int = 10_000):
        queue = list(requests)
        while queue and self.submit(queue[0]):
            queue.pop(0)
        steps = 0
        while any(self.active) and steps < max_steps:
            self.step()
            steps += 1
            while queue and self.submit(queue[0]):
                queue.pop(0)
        return requests
