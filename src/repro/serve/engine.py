"""Batched serving engine with a robustness control plane.

The engine drives ``Model.decode_step`` (jit'd once per shape) over a fixed
slot grid; finished requests free their slot for the next queued request
(continuous batching).  On top of the seed's decode loop it now carries the
control plane a trafficked system needs:

  * **admission control** — a bounded queue with backpressure: arrivals
    beyond ``max_queue`` are rejected, and a request is only bound to a
    slot when the KV page budget can host it;
  * **deadlines + retry** — per-request decode deadlines (virtual ticks per
    attempt); a timed-out attempt is torn down and retried under a bounded
    exponential backoff (:class:`repro.runtime.fault_tolerance.RestartPolicy`)
    until the retry budget fails it;
  * **preemption** — a victim sequence's KV is spilled to cold (through
    :class:`DispersedKVPool` in ``kv_mode='dispersed'``, host-side
    otherwise) and the request re-admitted later **bit-identically**;
  * **fault detection** — per-slot :class:`Heartbeat` records on the
    virtual clock feed a median-based :class:`StragglerPolicy`; a slot
    frozen by an injected fault accumulates strikes until the engine
    evicts (preempts) it — the same detection machinery the trainer uses;
  * **graceful degradation** — ``kv_mode='dispersed'`` pages each
    sequence's KV through a :class:`DispersedKVPool` (real bytes, same
    replacement policies as the paper's cVRF); pool misses cost virtual
    time (``fill_ticks``), so a smaller hot pool degrades latency instead
    of failing — and a live ``shrink_pool`` mid-service is survivable.

All timing is virtual (:class:`repro.serve.traffic.VirtualClock`): a run
is a pure function of (scenario, fault profile, seed), which is what makes
"chaos run == fault-free run, token for token" a testable claim.
"""

from __future__ import annotations

import collections
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies
from repro.models import get_model
from repro.runtime.fault_tolerance import (Heartbeat, RestartPolicy,
                                           StragglerPolicy)
from repro.serve.chaos import FaultInjector, FaultProfile
from repro.serve.kvcache import DispersedKVPool, PagePoolConfig
from repro.serve.traffic import Scenario, VirtualClock

# Request lifecycle states.
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
FAILED = "failed"
PREEMPTED = "preempted"


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- robustness control plane -------------------------------------
    rid: int = -1                     # engine-assigned if negative
    tenant: str = ""
    arrival_t: float = 0.0            # virtual ticks
    deadline: float | None = None     # ticks per attempt; None = best-effort
    status: str = QUEUED
    retries: int = 0
    preemptions: int = 0
    admit_t: float | None = None      # first admission to a slot
    first_token_t: float | None = None
    finish_t: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)


class ServeEngine:
    """Continuous-batching decode engine over ``slots`` sequences.

    ``kv_mode='resident'`` keeps KV fully resident (the seed behaviour);
    ``kv_mode='dispersed'`` pages it through a :class:`DispersedKVPool`
    whose hot capacity (``hot_pages``) bounds fast-memory use — pool fills
    and spills cost ``fill_ticks`` of virtual time each, which is how a
    too-small pool shows up as latency instead of an OOM.  Dispersed mode
    needs a paged cache layout (dense / MLA / encoder-decoder KV);
    recurrent-state families (SSM, hybrid) must serve resident.
    """

    STALL_FACTOR = 6.0    # heartbeat inflation of a frozen (failing) slot

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0,
                 kv_mode: str = "resident", page_size: int = 16,
                 hot_pages: int | None = None, cold_pages: int | None = None,
                 pool_policy: int = policies.FIFO,
                 max_queue: int = 64, base_step_ticks: float = 1.0,
                 fill_ticks: float = 0.05, spill_ticks: float = 0.05,
                 max_retries: int = 3, backoff_base: float = 2.0,
                 backoff_cap: float = 32.0,
                 straggler: StragglerPolicy | None = None,
                 clock: VirtualClock | None = None,
                 model=None, decode_fn=None):
        self.cfg = cfg
        self.model = model if model is not None else get_model(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.cache = self.model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int64)
        self.active: list[Request | None] = [None] * slots
        self._decode = decode_fn if decode_fn is not None \
            else jax.jit(self.model.decode_step)

        # -- virtual time + detection machinery --------------------------
        self.clock = clock if clock is not None else VirtualClock()
        self.base_step_ticks = base_step_ticks
        self.fill_ticks = fill_ticks
        self.spill_ticks = spill_ticks
        self.straggler = straggler if straggler is not None else \
            StragglerPolicy(threshold=2.5, strikes_to_evict=2,
                            window=4 * slots)
        self._heartbeats = [Heartbeat(host_id=s) for s in range(slots)]
        self._recs: list = []
        self.failing_until = np.zeros(slots, np.float64)
        self.chaos: FaultInjector | None = None

        # -- admission control -------------------------------------------
        self.max_queue = max_queue
        self.queue: collections.deque = collections.deque()  # of dict rows
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self._retry: dict[int, RestartPolicy] = {}
        self._suspended: dict[int, dict] = {}     # rid -> preempted state
        self._next_rid = 0

        # -- counters + telemetry ------------------------------------------
        self.rejected = 0
        self.preemptions = 0
        self.deadline_misses = 0
        self.timeouts = 0
        self.step_log: list[dict] = []
        self._step_no = 0

        # -- dispersed KV pool ---------------------------------------------
        self.kv_mode = kv_mode
        self.pool: DispersedKVPool | None = None
        if kv_mode == "dispersed":
            self._init_pool(page_size, hot_pages, cold_pages, pool_policy)
        elif kv_mode != "resident":
            raise ValueError(
                f"kv_mode must be 'resident' or 'dispersed', got {kv_mode!r}")

    # ------------------------------------------------------------- pool --
    def _init_pool(self, page_size, hot_pages, cold_pages, pool_policy):
        cfg = self.cfg
        if cfg.ssm or cfg.hybrid:
            raise ValueError(
                "kv_mode='dispersed' needs a paged KV layout; "
                f"{cfg.name} ({cfg.family}) carries recurrent state — "
                "serve it kv_mode='resident'")
        if self.max_len % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_len {self.max_len}")
        self.page_size = page_size
        self._pages_per_seq = self.max_len // page_size
        self._paged = tuple(k for k in ("k", "v", "c", "kr")
                            if k in self.cache)
        assert self._paged, "no paged cache tensors found"
        self._unpaged = tuple(k for k in self.cache if k not in self._paged)
        self._page_block = {
            k: (self.cache[k].shape[0], page_size)
            + tuple(self.cache[k].shape[3:]) for k in self._paged}
        flat = sum(int(np.prod(b)) for b in self._page_block.values())
        hot = hot_pages if hot_pages is not None \
            else max(self.slots + 2, self._pages_per_seq)
        if hot < self.slots + 2:
            raise ValueError(
                f"hot_pages={hot} too small: one pinned sink per slot plus "
                f"two evictable slots need >= {self.slots + 2}")
        cold = cold_pages if cold_pages is not None \
            else max(4 * self.slots, 8) * self._pages_per_seq
        self.pool = DispersedKVPool(PagePoolConfig(
            num_logical_pages=cold, num_hot_pages=hot, page_shape=(flat,),
            policy=pool_policy, pin_first=0, dtype=cfg.dtype))
        self._free_pages: collections.deque = collections.deque(range(cold))
        self._page_table: dict[int, list[int]] = {}
        self._pool_ops_seen = 0

    def _pack_page(self, s: int, pg: int) -> jnp.ndarray:
        lo, hi = pg * self.page_size, (pg + 1) * self.page_size
        return jnp.concatenate(
            [self.cache[k][:, s, lo:hi].reshape(-1) for k in self._paged])

    def _unpack_page(self, s: int, pg: int, flat: jnp.ndarray) -> None:
        lo, hi = pg * self.page_size, (pg + 1) * self.page_size
        off = 0
        for k in self._paged:
            block = self._page_block[k]
            n = int(np.prod(block))
            part = flat[off:off + n].reshape(block).astype(
                self.cache[k].dtype)
            self.cache[k] = self.cache[k].at[:, s, lo:hi].set(part)
            off += n

    def _used_pages(self, s: int) -> int:
        p = int(self.pos[s])
        return 0 if p <= 0 else (p - 1) // self.page_size + 1

    def _account_dispersed(self, s: int, req: Request) -> None:
        """Feed this step's access pattern through the pool: attention
        reads every history page (dense decode truth), the tail page takes
        this step's KV bytes (write-through)."""
        table = self._page_table[req.rid]
        pg = (int(self.pos[s]) - 1) // self.page_size
        for p in range(pg):
            self.pool.acquire(table[p], write=False)
        self.pool.write(table[pg], self._pack_page(s, pg))

    def kv_stats(self) -> dict:
        return self.pool.stats() if self.pool else {}

    # ------------------------------------------------------------ intake --
    def _reset_slot(self, s: int) -> None:
        """Zero slot ``s`` across all cache tensors: recurrent state (SSM /
        RG-LRU) would otherwise leak from the previous occupant of the slot
        (KV entries are masked by positions, but states carry over)."""
        for k, v in self.cache.items():
            self.cache[k] = v.at[:, s].set(0)

    def _validate(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(
                "empty prompt: a Request(prompt=[]) has no token to feed "
                "the decoder (the engine would loop on token 0 forever); "
                "prefill at least one token (e.g. a BOS id)")
        if req.rid < 0:
            req.rid = self._next_rid
            self._next_rid += 1
        else:
            self._next_rid = max(self._next_rid, req.rid + 1)

    def submit(self, req: Request) -> bool:
        """Legacy direct admission: bind ``req`` to a free slot now.
        Returns False when no slot (or KV page budget) is available."""
        self._validate(req)
        return self._try_admit(req, self.clock.now)

    def enqueue(self, req: Request) -> bool:
        """Admission-controlled intake: queue the request, or reject it
        (backpressure) when the bounded queue is full."""
        self._validate(req)
        if len(self.queue) >= self.max_queue:
            req.status = REJECTED
            req.finish_t = self.clock.now
            self.rejected += 1
            return False
        self.queue.append(dict(req=req, eligible_at=self.clock.now))
        return True

    def _requeue(self, req: Request, *, delay: float = 0.0,
                 front: bool = False) -> None:
        entry = dict(req=req, eligible_at=self.clock.now + delay)
        if front:
            self.queue.appendleft(entry)
        else:
            self.queue.append(entry)

    def _free_slot(self, now: float) -> int | None:
        for s in range(self.slots):
            if self.active[s] is None and now >= self.failing_until[s]:
                return s
        return None

    def _try_admit(self, req: Request, now: float) -> bool:
        s = self._free_slot(now)
        if s is None:
            return False
        if self.pool is not None and req.rid not in self._page_table:
            if len(self._free_pages) < self._pages_per_seq:
                return False                      # page-budget backpressure
            self._page_table[req.rid] = [
                self._free_pages.popleft()
                for _ in range(self._pages_per_seq)]
        self._reset_slot(s)
        sus = self._suspended.pop(req.rid, None)
        if sus is not None:                       # bit-identical resume
            for k, v in sus["host"].items():
                self.cache[k] = self.cache[k].at[:, s].set(jnp.asarray(v))
            if self.pool is not None:
                table = self._page_table[req.rid]
                for p in range(sus["pages"]):
                    self._unpack_page(s, p, self.pool.read(table[p]))
            self.pos[s] = sus["pos"]
        else:
            self.pos[s] = 0
        if self.pool is not None:
            self.pool.pin(self._page_table[req.rid][0])   # attention sink
        self.active[s] = req
        req.status = RUNNING
        if req.admit_t is None:
            req.admit_t = now
        req._deadline_at = (now + req.deadline
                            if req.deadline is not None else None)
        return True

    def _admit_from_queue(self, now: float) -> None:
        """Bind eligible queued requests to free slots, FIFO with head-of-
        line blocking (a head that cannot get a slot or pages holds the
        queue — that is the backpressure)."""
        while self.queue:
            head = None
            for entry in self.queue:              # first eligible entry
                if entry["eligible_at"] <= now:
                    head = entry
                    break
            if head is None or not self._try_admit(head["req"], now):
                return
            self.queue.remove(head)

    # -------------------------------------------------------- fault API --
    def fail_slot(self, s: int, *, until: float) -> None:
        """Freeze slot ``s`` until virtual time ``until`` (chaos hook):
        it makes no progress and its heartbeat inflates so the straggler
        policy can find it."""
        self.failing_until[s] = max(self.failing_until[s], until)

    def shrink_pool(self, new_hot_pages: int) -> int:
        """Live memory-pressure event: shrink the hot pool (dispersed mode;
        resident engines have nothing to shrink).  Returns pages spilled."""
        if self.pool is None:
            return 0
        floor = len(self.pool._pin_set) + 2
        return self.pool.shrink(max(int(new_hot_pages), floor))

    def preempt(self, s: int, reason: str = "") -> Request | None:
        """Spill slot ``s``'s sequence to cold and re-queue it (front).
        In dispersed mode the paged KV goes through the pool's cold
        region; host-side snapshots carry whatever is not paged.  The
        resumed request continues bit-identically."""
        req = self.active[s]
        if req is None:
            return None
        host_keys = self.cache if self.pool is None else self._unpaged
        snap = {k: np.asarray(self.cache[k][:, s]) for k in host_keys}
        pages = self._used_pages(s) if self.pool is not None else 0
        if self.pool is not None:
            table = self._page_table[req.rid]
            self.pool.unpin(table[0])
            for p in range(pages):
                self.pool.evict(table[p])         # writeback -> cold
        self._suspended[req.rid] = dict(
            pos=int(self.pos[s]), host=snap, pages=pages, reason=reason)
        req.status = PREEMPTED
        req.preemptions += 1
        self.preemptions += 1
        self.active[s] = None
        self._requeue(req, front=True)

        return req

    def _release_request(self, req: Request) -> None:
        self._suspended.pop(req.rid, None)
        self._retry.pop(req.rid, None)
        if self.pool is not None:
            table = self._page_table.pop(req.rid, None)
            if table:
                for p in table:
                    self.pool.release(p)
                self._free_pages.extend(table)

    def _finish(self, s: int, status: str, now: float) -> None:
        req = self.active[s]
        req.status = status
        req.done = status == DONE
        req.finish_t = now
        self.active[s] = None
        self._release_request(req)

    def _timeout(self, s: int, now: float) -> None:
        """Deadline miss: tear the attempt down and retry under bounded
        exponential backoff, or fail it when the budget is spent."""
        req = self.active[s]
        self.deadline_misses += 1
        self.timeouts += 1
        self.active[s] = None
        self._suspended.pop(req.rid, None)
        if self.pool is not None:                 # fresh attempt: pages
            table = self._page_table.pop(req.rid, None)     # released
            if table:
                for p in table:
                    self.pool.release(p)
                self._free_pages.extend(table)
        req.out.clear()
        req.token_times.clear()
        req.first_token_t = None
        rp = self._retry.setdefault(req.rid, RestartPolicy(
            max_restarts=self.max_retries, backoff_base=self.backoff_base,
            backoff_cap=self.backoff_cap))
        delay = rp.next_delay()
        if delay is None:
            req.status = FAILED
            req.finish_t = now
            self._release_request(req)
            return
        req.status = QUEUED
        req.retries += 1
        self._requeue(req, delay=delay)

    def _check_deadlines(self, now: float) -> None:
        for s in range(self.slots):
            req = self.active[s]
            if (req is not None and req._deadline_at is not None
                    and now > req._deadline_at):
                self._timeout(s, now)

    def _observe_stragglers(self) -> None:
        if not self._recs:
            return
        verdicts = self.straggler.observe(self._recs)
        for s, verdict in verdicts.items():
            if verdict == "evict" and self.active[s] is not None:
                self.preempt(s, reason="straggler-evict")

    # ------------------------------------------------------------- steps --
    def _batch(self, tokens_np, positions_np):
        b = {"tokens": jnp.asarray(tokens_np, jnp.int32),
             "positions": jnp.asarray(positions_np, jnp.int32)}
        if self.cfg.positional == "mrope":
            b["positions3"] = jnp.broadcast_to(
                b["positions"][None], (3,) + b["positions"].shape)
        if self.cfg.encoder_decoder:
            pass  # cross-KV prepared at submission time by the audio stub
        return b

    def step(self) -> list[tuple[Request, int]]:
        """One engine step: feed each active slot its next token (prompt
        token during prefill-by-decode, else the last sampled token),
        advance the virtual clock by the step's duration (chaos latency
        multiplier + KV pool traffic), and run detection/bookkeeping."""
        now0 = self.clock.now
        self._step_no += 1
        mult = (self.chaos.latency_multiplier(now0)
                if self.chaos is not None else 1.0)
        frozen = {s for s in range(self.slots)
                  if now0 < self.failing_until[s]
                  and self.active[s] is not None}
        occupied = [s for s in range(self.slots)
                    if self.active[s] is not None]
        # A frozen slot makes no progress: its cache slice is rolled back
        # after the decode so injected faults cannot corrupt state.
        rollback = {s: {k: self.cache[k][:, s] for k in self.cache}
                    for s in frozen}

        tokens = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            p = int(self.pos[s])
            if p < len(req.prompt):
                tokens[s, 0] = req.prompt[p]
            elif req.out:
                tokens[s, 0] = req.out[-1]
        positions = self.pos[:, None].astype(np.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, self._batch(tokens, positions))
        logits = np.asarray(logits[:, 0], np.float32)
        for s, slices in rollback.items():
            for k, v in slices.items():
                self.cache[k] = self.cache[k].at[:, s].set(v)

        emitted = []
        finished = []
        for s, req in enumerate(self.active):
            if req is None or s in frozen:
                continue
            self.pos[s] += 1
            if self.pos[s] < len(req.prompt):
                continue                       # still consuming the prompt
            if self.temperature > 0:
                self.key, k = jax.random.split(self.key)
                tok = int(jax.random.categorical(
                    k, jnp.asarray(logits[s]) / self.temperature))
            else:
                tok = int(np.argmax(logits[s]))
            req.out.append(tok)
            emitted.append((req, tok))
            if (len(req.out) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                finished.append(s)

        if self.pool is not None:
            for s, req in enumerate(self.active):
                if req is not None and s not in frozen and s not in finished:
                    self._account_dispersed(s, req)
            ops = self.pool.fills + self.pool.spills
            pool_ticks = ((self.pool.fills + self.pool.spills
                           - self._pool_ops_seen) * self.fill_ticks)
            self._pool_ops_seen = ops
        else:
            pool_ticks = 0.0

        dur = self.base_step_ticks * mult + pool_ticks
        now = self.clock.advance(dur)
        for req, _tok in emitted:
            if req.first_token_t is None:
                req.first_token_t = now
            req.token_times.append(now)
        for s in finished:
            self._finish(s, DONE, now)

        for s in occupied:
            slot_dur = dur * (self.STALL_FACTOR if s in frozen else 1.0)
            rec = self._heartbeats[s].beat(self._step_no, now=now,
                                           step_time=slot_dur)
            self._recs.append(rec)
        if len(self._recs) > 1000:
            del self._recs[:500]

        self.step_log.append(dict(
            t=now, dur=dur, emitted=len(emitted),
            active=len(occupied), frozen=len(frozen),
            degraded=bool(mult > 1.0 or frozen
                          or (self.pool is not None
                              and self.pool.shrinks > 0))))
        return emitted

    # --------------------------------------------------------- front door --
    def run(self, requests: list[Request], max_steps: int = 10_000):
        """Legacy driver: direct submission, no queue/deadlines/chaos."""
        queue = list(requests)
        while queue and self.submit(queue[0]):
            queue.pop(0)
        steps = 0
        while any(r is not None for r in self.active) and steps < max_steps:
            self.step()
            steps += 1
            while queue and self.submit(queue[0]):
                queue.pop(0)
        return requests

    def serve(self, scenario, *, chaos=None,
              max_steps: int = 50_000) -> list[Request]:
        """Drive a full scenario on the virtual clock: arrivals enter the
        bounded admission queue as the clock passes their arrival time,
        chaos events fire on schedule, and the loop runs until every
        request reaches a terminal state (DONE / FAILED / REJECTED).

        ``chaos`` is a :class:`FaultProfile` or a prepared
        :class:`FaultInjector`; ``scenario`` is a
        :class:`repro.serve.traffic.Scenario` or a plain request list
        (arrival times read from ``Request.arrival_t``).
        """
        if isinstance(scenario, Scenario):
            requests = scenario.requests()
        else:
            requests = list(scenario)
        if isinstance(chaos, FaultProfile):
            chaos = FaultInjector(chaos)
        self.chaos = chaos
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_t, r.rid)))
        steps = 0
        while steps < max_steps:
            now = self.clock.now
            while pending and pending[0].arrival_t <= now:
                self.enqueue(pending.popleft())
            if self.chaos is not None:
                self.chaos.apply(self, now)
            self._admit_from_queue(now)
            if not any(r is not None for r in self.active):
                nxt = self._next_event_time(pending)
                if nxt is None:
                    break                          # everything terminal
                self.clock.advance_to(nxt + 1e-9)
                continue
            self.step()
            steps += 1
            now = self.clock.now
            self._check_deadlines(now)
            self._observe_stragglers()
        return requests

    def _next_event_time(self, pending) -> float | None:
        """Earliest future event while idle: next arrival, next queued
        request turning eligible, or a quarantined slot healing."""
        times = []
        if pending:
            times.append(pending[0].arrival_t)
        if self.queue:
            times.append(min(e["eligible_at"] for e in self.queue))
            # queue blocked on quarantined slots: wait for one to heal
            if all(self.active[s] is not None
                   or self.clock.now < self.failing_until[s]
                   for s in range(self.slots)):
                times.append(float(self.failing_until.min()))
        return min(times) if times else None
