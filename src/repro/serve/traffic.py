"""Serving-scenario generator: seeded, replayable traffic for the engine.

Everything runs on a **virtual clock** (ticks = nominal decode steps), so a
scenario is a pure function of its :class:`TrafficConfig` and a seed —
every run is deterministic and bit-replayable, which is what lets the chaos
harness compare a faulted run against its fault-free twin request by
request.

Arrival processes:

  * ``poisson``  — memoryless arrivals at ``rate`` requests/tick;
  * ``mmpp``     — a 2-state Markov-modulated Poisson process: a *calm*
    state at ``rate`` and a *burst* state at ``rate * burst_factor``,
    switching with geometric dwell times — the bursty, correlated traffic
    real serving fleets see.

Requests are **multi-tenant**: each tenant maps to one model architecture
from :mod:`repro.configs.registry` and carries its own prompt/output
length distributions — prefill-heavy tenants (VLM/audio: long prompts,
short outputs) mixed with decode-heavy ones (SSM/hybrid chat: short
prompts, long outputs), so one scenario exercises mixed prefill/decode the
way a shared fleet does.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import registry

__all__ = [
    "VirtualClock", "Tenant", "TrafficConfig", "RequestSpec", "Scenario",
    "default_tenants", "TRAFFIC_MIXES", "generate",
]


class VirtualClock:
    """Deterministic virtual time in ticks (1 tick = one nominal decode
    step).  The engine advances it; nothing ever reads wall time, so runs
    are replayable regardless of host load."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, dt: float) -> float:
        assert dt >= 0, f"virtual time cannot run backwards (dt={dt})"
        self.now += float(dt)
        return self.now

    def advance_to(self, t: float) -> float:
        if t > self.now:
            self.now = float(t)
        return self.now


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One traffic tenant: an architecture from the configs registry plus
    its sequence-length profile (geometric-ish lengths, clamped)."""

    name: str
    arch: str                       # key into repro.configs.registry.ARCHS
    prompt_mean: float = 8.0
    prompt_max: int = 32
    decode_mean: float = 8.0
    decode_max: int = 32
    deadline: float | None = None   # ticks from admission; None = best-effort
    weight: float = 1.0             # relative arrival share


def default_tenants(max_len: int = 48, vocab: int = 512) -> tuple[Tenant, ...]:
    """One tenant per registry family, sequence-length profiles keyed by
    what the family is used for: prefill-heavy (vlm/audio: long prompts,
    short outputs), decode-heavy (ssm/hybrid: short prompts, long
    outputs), balanced (dense/moe chat)."""
    del vocab
    half = max(max_len // 2, 8)
    profiles = {
        "dense":  dict(prompt_mean=half * 0.3, decode_mean=half * 0.5,
                       deadline=None, weight=3.0),
        "moe":    dict(prompt_mean=half * 0.4, decode_mean=half * 0.4,
                       deadline=None, weight=1.0),
        "ssm":    dict(prompt_mean=half * 0.15, decode_mean=half * 0.8,
                       deadline=None, weight=2.0),
        "hybrid": dict(prompt_mean=half * 0.15, decode_mean=half * 0.7,
                       deadline=None, weight=1.0),
        "vlm":    dict(prompt_mean=half * 0.8, decode_mean=half * 0.2,
                       deadline=None, weight=1.0),
        "audio":  dict(prompt_mean=half * 0.7, decode_mean=half * 0.25,
                       deadline=None, weight=1.0),
    }
    seen: dict[str, Tenant] = {}
    for name, cfg in registry.ARCHS.items():
        if cfg.family in seen:
            continue
        p = profiles.get(cfg.family, profiles["dense"])
        seen[cfg.family] = Tenant(
            name=cfg.family, arch=name,
            prompt_mean=max(p["prompt_mean"], 1.0),
            prompt_max=max_len // 2,
            decode_mean=max(p["decode_mean"], 1.0),
            decode_max=max_len // 2,
            deadline=p["deadline"], weight=p["weight"])
    return tuple(seen[f] for f in sorted(seen))


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """A declarative traffic mix; ``generate(cfg, seed)`` makes it a
    concrete :class:`Scenario`."""

    name: str = "steady"
    arrival: str = "poisson"        # poisson | mmpp
    rate: float = 0.25              # requests per tick (calm state)
    burst_factor: float = 6.0       # mmpp: burst-state rate multiplier
    p_enter_burst: float = 0.02     # mmpp: calm -> burst per tick
    p_exit_burst: float = 0.15      # mmpp: burst -> calm per tick
    n_requests: int = 16
    tenants: tuple[Tenant, ...] = ()
    deadline: float | None = None   # default deadline for tenants without
    vocab: int = 512
    max_len: int = 48

    def __post_init__(self):
        if self.arrival not in ("poisson", "mmpp"):
            raise ValueError(
                f"arrival must be 'poisson' or 'mmpp', got {self.arrival!r}")
        if not self.tenants:
            object.__setattr__(self, "tenants",
                               default_tenants(self.max_len, self.vocab))


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One immutable arrival: everything needed to materialise a fresh
    ``Request``, so a scenario can be replayed (fault-free vs chaos) from
    identical inputs."""

    rid: int
    t: float
    tenant: str
    prompt: tuple[int, ...]
    max_new_tokens: int
    deadline: float | None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A seeded, replayable serving scenario: sorted arrival specs."""

    config: TrafficConfig
    seed: int
    arrivals: tuple[RequestSpec, ...]

    @property
    def horizon(self) -> float:
        return self.arrivals[-1].t if self.arrivals else 0.0

    def requests(self):
        """Fresh mutable Request objects for one run (import here: engine
        imports traffic for the clock, so the reverse import is lazy)."""
        from repro.serve.engine import Request
        return [Request(prompt=list(s.prompt),
                        max_new_tokens=s.max_new_tokens, rid=s.rid,
                        tenant=s.tenant, arrival_t=s.t, deadline=s.deadline)
                for s in self.arrivals]


def _interarrival_times(cfg: TrafficConfig, rng) -> np.ndarray:
    """Virtual-tick arrival times for ``cfg.n_requests`` requests."""
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate, cfg.n_requests)
        return np.cumsum(gaps)
    # MMPP-2: walk tick by tick; each tick in state s arrivals ~ thinned
    # exponential stream at rate_s.  Implemented as per-request gap draws
    # with the modulating chain advanced underneath the exponential draw.
    times = []
    t = 0.0
    burst = False
    for _ in range(cfg.n_requests):
        while True:
            rate = cfg.rate * (cfg.burst_factor if burst else 1.0)
            gap = rng.exponential(1.0 / rate)
            # chain switches are checked per elapsed tick of the gap
            switch_p = cfg.p_exit_burst if burst else cfg.p_enter_burst
            n_ticks = max(int(gap), 1)
            flips = rng.random(n_ticks) < switch_p
            if flips.any():
                # the chain flipped mid-gap: advance to the flip and redraw
                t += float(np.argmax(flips) + 1)
                burst = not burst
                continue
            t += gap
            break
        times.append(t)
    return np.asarray(times)


def _draw_len(rng, mean: float, lo: int, hi: int) -> int:
    """Geometric length draw with the given mean, clamped to [lo, hi]."""
    p = min(max(1.0 / max(mean, 1.0), 1e-6), 1.0)
    return int(np.clip(rng.geometric(p), lo, hi))


def generate(cfg: TrafficConfig, seed: int = 0) -> Scenario:
    """The one entry point: a deterministic scenario from (config, seed)."""
    rng = np.random.default_rng(seed)
    times = _interarrival_times(cfg, rng)
    weights = np.asarray([t.weight for t in cfg.tenants], np.float64)
    weights /= weights.sum()
    specs = []
    for rid, t in enumerate(times):
        ten = cfg.tenants[int(rng.choice(len(cfg.tenants), p=weights))]
        n_prompt = _draw_len(rng, ten.prompt_mean, 1, ten.prompt_max)
        n_out = _draw_len(rng, ten.decode_mean, 1, ten.decode_max)
        prompt = tuple(int(x) for x in
                       rng.integers(1, cfg.vocab, n_prompt))
        deadline = ten.deadline if ten.deadline is not None else cfg.deadline
        specs.append(RequestSpec(rid=rid, t=float(t), tenant=ten.name,
                                 prompt=prompt, max_new_tokens=n_out,
                                 deadline=deadline))
    return Scenario(config=cfg, seed=seed, arrivals=tuple(specs))


# Named mixes the SLO benchmark sweeps over.  ``steady`` is uniform Poisson
# load; ``bursty`` is the MMPP regime where admission control earns its
# keep; ``decode_heavy`` skews the tenant mix to long decodes (KV pressure).
TRAFFIC_MIXES: dict[str, TrafficConfig] = {
    "steady": TrafficConfig(name="steady", arrival="poisson", rate=0.20),
    "bursty": TrafficConfig(name="bursty", arrival="mmpp", rate=0.10,
                            burst_factor=8.0),
    "decode_heavy": TrafficConfig(
        name="decode_heavy", arrival="poisson", rate=0.15,
        tenants=tuple(dataclasses.replace(t, decode_mean=t.decode_mean * 2,
                                          weight=(3.0 if t.name in
                                                  ("ssm", "hybrid")
                                                  else t.weight))
                      for t in default_tenants())),
}
