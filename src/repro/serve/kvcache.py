"""Dispersed KV cache: the paper's Register Dispersion mechanism applied to
serving-time KV pages (DESIGN.md §2.B).

Mapping of the paper's concepts:

  architectural vector registers  ->  logical KV pages (page_size tokens)
  compact VRF (cVRF)              ->  hot page pool in fast memory
  reserved per-register address   ->  each logical page's fixed slot in the
                                      cold (host/HBM-overflow) region
  v0 pinned                       ->  attention-sink pages pinned hot
  FIFO replacement                ->  same policies module as the cVRF

The pool controller is the *same* victim-selection code (`core.policies`)
driving the hardware simulator, so the paper's policy results (FIFO is
enough; Fig 4/5) transfer measurably: `stats()` reports hit rates that the
serving benchmark compares against the cVRF curves.

Beyond the paper, the pool is a *live-degradable* resource: ``shrink()``
reduces the hot capacity mid-service (forced spill of policy-selected
victims, then continued operation from the smaller pool) — the memory-
pressure lever ``repro.serve.chaos`` pulls — and ``pin``/``unpin``/
``evict``/``release`` give the serving engine explicit page lifetime
control (sink pinning per active sequence, spill-to-cold on preemption,
free-on-completion).

This is a host-side controller managing device arrays; on a real cluster the
cold region lives in host RAM and transfers overlap decode steps.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies


@dataclasses.dataclass
class PagePoolConfig:
    num_logical_pages: int          # "architectural registers"
    num_hot_pages: int              # "compact VRF" capacity
    page_shape: tuple               # per-page array shape, e.g. (P, Hkv, D)
    policy: int = policies.FIFO
    pin_first: int = 1              # attention sinks (the v0 analogue)
    dtype: str = "bfloat16"


class DispersedKVPool:
    """Hot pool + cold overflow, FIFO/LRU/OPT-policied, per KV tensor."""

    def __init__(self, cfg: PagePoolConfig):
        assert cfg.num_hot_pages >= 2 + cfg.pin_first
        self.cfg = cfg
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.hot = jnp.zeros((cfg.num_hot_pages,) + cfg.page_shape, dt)
        self.cold = jnp.zeros((cfg.num_logical_pages,) + cfg.page_shape, dt)
        n = cfg.num_hot_pages
        self.tags = np.full(n, -1, np.int64)
        self.dirty = np.zeros(n, bool)
        self.ins_seq = np.zeros(n, np.int64)
        self.last_use = np.zeros(n, np.int64)
        self.freq = np.zeros(n, np.int64)
        self.next_use = np.zeros(n, np.int64)
        self.pinned = np.zeros(n, bool)
        self._pin_set: set[int] = set(range(cfg.pin_first))
        self._seq = 0
        self._now = 0
        self.reset_stats()

    # ------------------------------------------------------------- cache --
    def _slot_of(self, page: int) -> int | None:
        w = np.nonzero(self.tags == page)[0]
        return int(w[0]) if w.size else None

    def acquire(self, page: int, *, write: bool) -> int:
        """Make logical ``page`` hot; returns its hot-slot index."""
        assert 0 <= page < self.cfg.num_logical_pages
        self._now += 1
        s = self._slot_of(page)
        if s is not None:
            self.hits += 1
            self.last_use[s] = self._now
            self.freq[s] += 1
            self.dirty[s] |= write
            return s
        self.misses += 1
        free = np.nonzero(self.tags < 0)[0]
        if free.size:
            s = int(free[0])
        else:
            s = policies.np_select_victim(
                self.tags, self.ins_seq, self.last_use, self.freq,
                self.next_use, self.pinned, self.cfg.num_hot_pages,
                self.cfg.policy)
            if self.dirty[s]:
                self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
                self.spills += 1
        self.hot = self.hot.at[s].set(self.cold[page])
        self.fills += 1
        self.tags[s] = page
        self.dirty[s] = write
        self._seq += 1
        self.ins_seq[s] = self._seq
        self.last_use[s] = self._now
        self.freq[s] = 1
        self.pinned[s] = page in self._pin_set
        return s

    def read(self, page: int) -> jnp.ndarray:
        s = self.acquire(page, write=False)   # may rebind self.hot (fill)
        return self.hot[s]

    def write(self, page: int, value) -> None:
        s = self.acquire(page, write=True)
        self.hot = self.hot.at[s].set(value.astype(self.hot.dtype))

    def flush(self) -> jnp.ndarray:
        """Spill everything; returns the full logical tensor (cold view).
        Idempotent: a second flush with no intervening writes is a no-op."""
        for s in range(self.cfg.num_hot_pages):
            if self.tags[s] >= 0 and self.dirty[s]:
                self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
                self.dirty[s] = False
        return self.cold

    # ----------------------------------------------------- page lifetime --
    def pin(self, page: int) -> None:
        """Pin ``page`` hot from now on (the per-sequence attention-sink
        analogue of the paper's v0).  The pool refuses to pin its whole
        capacity: at least two slots must stay evictable."""
        if page in self._pin_set:
            return
        if len(self._pin_set) >= self.cfg.num_hot_pages - 2:
            raise ValueError(
                f"cannot pin page {page}: {len(self._pin_set)} of "
                f"{self.cfg.num_hot_pages} hot slots already pinned "
                "(two must stay evictable)")
        self._pin_set.add(page)
        s = self._slot_of(page)
        if s is not None:
            self.pinned[s] = True

    def unpin(self, page: int) -> None:
        self._pin_set.discard(page)
        s = self._slot_of(page)
        if s is not None:
            self.pinned[s] = False

    def evict(self, page: int) -> None:
        """Force ``page`` out of the hot pool (writeback to cold if dirty).
        The cold copy stays valid — this is the preemption spill path."""
        s = self._slot_of(page)
        if s is None:
            return
        if self.dirty[s]:
            self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
            self.spills += 1
        self._drop_slot(s)

    def release(self, page: int) -> None:
        """Discard ``page`` entirely (no writeback): its hot slot is freed
        and the cold copy is considered garbage — completion/abort path."""
        self.unpin(page)
        s = self._slot_of(page)
        if s is not None:
            self._drop_slot(s)

    def _drop_slot(self, s: int) -> None:
        self.tags[s] = -1
        self.dirty[s] = False
        self.pinned[s] = False
        self.ins_seq[s] = self.last_use[s] = 0
        self.freq[s] = self.next_use[s] = 0

    # -------------------------------------------------- graceful shrink --
    def shrink(self, new_hot_pages: int) -> int:
        """Shrink the hot pool *live* to ``new_hot_pages`` slots: victims
        are selected by the configured replacement policy (pinned pages
        survive), dirty victims are spilled to cold, and service continues
        from the smaller pool.  Returns the number of pages spilled.

        This is the memory-pressure event of the chaos harness — the
        paper's economics (a physically smaller pool degrades through the
        hierarchy instead of failing) exercised while serving.
        """
        n = self.cfg.num_hot_pages
        if new_hot_pages >= n:
            return 0
        if new_hot_pages < max(2 + len(self._pin_set), 2):
            raise ValueError(
                f"cannot shrink hot pool to {new_hot_pages}: "
                f"{len(self._pin_set)} pinned pages + 2 evictable slots "
                "must fit")
        drop: list[int] = []
        spilled = 0
        for _ in range(n - new_hot_pages):
            # Prefer free slots; otherwise the policy picks the victim
            # among slots not already scheduled for removal.
            tags = self.tags.copy()
            tags[drop] = -2                       # poison: neither free
            pinned = self.pinned.copy()           # nor evictable
            pinned[drop] = True
            free = np.nonzero(tags == -1)[0]
            if free.size:
                drop.append(int(free[0]))
                continue
            s = policies.np_select_victim(
                tags, self.ins_seq, self.last_use, self.freq,
                self.next_use, pinned, n, self.cfg.policy)
            if self.dirty[s]:
                self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
                self.spills += 1
                spilled += 1
            drop.append(s)
        keep = np.asarray([i for i in range(n) if i not in set(drop)],
                          np.int64)
        self.hot = self.hot[jnp.asarray(keep)]
        for name in ("tags", "dirty", "ins_seq", "last_use", "freq",
                     "next_use", "pinned"):
            setattr(self, name, getattr(self, name)[keep])
        self.cfg.num_hot_pages = new_hot_pages
        self.shrinks += 1
        return spilled

    # --------------------------------------------------------- accounting --
    def reset_stats(self) -> None:
        """Zero the access counters (hits/misses/spills/fills/shrinks) so a
        pool can be reused across sweep points — or a steady-state window
        measured after warm-up — without stat bleed.  Cache *contents* are
        untouched."""
        self.hits = self.misses = self.spills = self.fills = 0
        self.shrinks = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return dict(hits=self.hits, misses=self.misses,
                    hit_rate=self.hits / max(total, 1), spills=self.spills,
                    fills=self.fills, shrinks=self.shrinks,
                    hot_pages=int(self.cfg.num_hot_pages),
                    pinned_pages=len(self._pin_set),
                    hot_bytes=int(np.prod(self.hot.shape))
                    * self.hot.dtype.itemsize,
                    cold_bytes=int(np.prod(self.cold.shape))
                    * self.cold.dtype.itemsize)
