"""Dispersed KV cache: the paper's Register Dispersion mechanism applied to
serving-time KV pages (DESIGN.md §2.B).

Mapping of the paper's concepts:

  architectural vector registers  ->  logical KV pages (page_size tokens)
  compact VRF (cVRF)              ->  hot page pool in fast memory
  reserved per-register address   ->  each logical page's fixed slot in the
                                      cold (host/HBM-overflow) region
  v0 pinned                       ->  attention-sink pages pinned hot
  FIFO replacement                ->  same policies module as the cVRF

The pool controller is the *same* victim-selection code (`core.policies`)
driving the hardware simulator, so the paper's policy results (FIFO is
enough; Fig 4/5) transfer measurably: `stats()` reports hit rates that the
serving benchmark compares against the cVRF curves.

This is a host-side controller managing device arrays; on a real cluster the
cold region lives in host RAM and transfers overlap decode steps.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies


@dataclasses.dataclass
class PagePoolConfig:
    num_logical_pages: int          # "architectural registers"
    num_hot_pages: int              # "compact VRF" capacity
    page_shape: tuple               # per-page array shape, e.g. (P, Hkv, D)
    policy: int = policies.FIFO
    pin_first: int = 1              # attention sinks (the v0 analogue)
    dtype: str = "bfloat16"


class DispersedKVPool:
    """Hot pool + cold overflow, FIFO/LRU/OPT-policied, per KV tensor."""

    def __init__(self, cfg: PagePoolConfig):
        assert cfg.num_hot_pages >= 2 + cfg.pin_first
        self.cfg = cfg
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.hot = jnp.zeros((cfg.num_hot_pages,) + cfg.page_shape, dt)
        self.cold = jnp.zeros((cfg.num_logical_pages,) + cfg.page_shape, dt)
        n = cfg.num_hot_pages
        self.tags = np.full(n, -1, np.int64)
        self.dirty = np.zeros(n, bool)
        self.ins_seq = np.zeros(n, np.int64)
        self.last_use = np.zeros(n, np.int64)
        self.freq = np.zeros(n, np.int64)
        self.next_use = np.zeros(n, np.int64)
        self.pinned = np.zeros(n, bool)
        self._seq = 0
        self._now = 0
        self.hits = self.misses = self.spills = self.fills = 0

    # ------------------------------------------------------------- cache --
    def _slot_of(self, page: int) -> int | None:
        w = np.nonzero(self.tags == page)[0]
        return int(w[0]) if w.size else None

    def acquire(self, page: int, *, write: bool) -> int:
        """Make logical ``page`` hot; returns its hot-slot index."""
        assert 0 <= page < self.cfg.num_logical_pages
        self._now += 1
        s = self._slot_of(page)
        if s is not None:
            self.hits += 1
            self.last_use[s] = self._now
            self.freq[s] += 1
            self.dirty[s] |= write
            return s
        self.misses += 1
        free = np.nonzero(self.tags < 0)[0]
        if free.size:
            s = int(free[0])
        else:
            s = policies.np_select_victim(
                self.tags, self.ins_seq, self.last_use, self.freq,
                self.next_use, self.pinned, self.cfg.num_hot_pages,
                self.cfg.policy)
            if self.dirty[s]:
                self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
                self.spills += 1
        self.hot = self.hot.at[s].set(self.cold[page])
        self.fills += 1
        self.tags[s] = page
        self.dirty[s] = write
        self._seq += 1
        self.ins_seq[s] = self._seq
        self.last_use[s] = self._now
        self.freq[s] = 1
        self.pinned[s] = page < self.cfg.pin_first
        return s

    def read(self, page: int) -> jnp.ndarray:
        s = self.acquire(page, write=False)   # may rebind self.hot (fill)
        return self.hot[s]

    def write(self, page: int, value) -> None:
        s = self.acquire(page, write=True)
        self.hot = self.hot.at[s].set(value.astype(self.hot.dtype))

    def flush(self) -> jnp.ndarray:
        """Spill everything; returns the full logical tensor (cold view)."""
        for s in range(self.cfg.num_hot_pages):
            if self.tags[s] >= 0 and self.dirty[s]:
                self.cold = self.cold.at[int(self.tags[s])].set(self.hot[s])
                self.dirty[s] = False
        return self.cold

    def stats(self) -> dict:
        total = self.hits + self.misses
        return dict(hits=self.hits, misses=self.misses,
                    hit_rate=self.hits / max(total, 1), spills=self.spills,
                    fills=self.fills,
                    hot_bytes=int(np.prod(self.hot.shape))
                    * self.hot.dtype.itemsize,
                    cold_bytes=int(np.prod(self.cold.shape))
                    * self.cold.dtype.itemsize)
