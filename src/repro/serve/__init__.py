from repro.serve import chaos, engine, kvcache, slo, traffic
from repro.serve.chaos import (FAULT_PROFILES, FaultEvent, FaultInjector,
                               FaultProfile, make_profile)
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import DispersedKVPool, PagePoolConfig
from repro.serve.slo import SLOReport, summarize
from repro.serve.traffic import (TRAFFIC_MIXES, Scenario, Tenant,
                                 TrafficConfig, VirtualClock, generate)

__all__ = [
    "engine", "kvcache", "traffic", "chaos", "slo",
    "Request", "ServeEngine", "DispersedKVPool", "PagePoolConfig",
    "VirtualClock", "Tenant", "TrafficConfig", "Scenario", "generate",
    "TRAFFIC_MIXES", "FaultEvent", "FaultProfile", "FaultInjector",
    "make_profile", "FAULT_PROFILES", "SLOReport", "summarize",
]
