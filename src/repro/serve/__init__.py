from repro.serve import engine, kvcache
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import DispersedKVPool, PagePoolConfig
__all__ = ["engine", "kvcache", "Request", "ServeEngine",
           "DispersedKVPool", "PagePoolConfig"]
