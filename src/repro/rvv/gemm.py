"""im2col GEMM kernel backing the DenseNet121-L105 and ResNet50-L10 rows of
Table 2 (the paper maps those CNN layers to matrix multiplication).

C(M,N) = A(M,K) @ B(K,N), vectorised along N.  Inner K loop streams one
broadcast A element + one B row chunk into a single accumulator: exactly 4
active vregs (acc, a, b, zero), matching Table 3's "4 active registers"
for both CNN layers.
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

DENSENET = dict(m=32, k=1152, n=64)      # DenseNet121 layer 105 (im2col)
RESNET = dict(m=128, k=256, n=784)       # ResNet50 layer 10 (im2col)
PAPER = DENSENET
REDUCED = dict(m=4, k=16, n=16)

ACC, AR, BR, ZR = 1, 2, 3, 31


@common.register_benchmark(
    "resnet50_l10", domain="CNN", paper_params=RESNET,
    reduced_params=REDUCED, table2="(128 x 256)x(256 x 784)")
@common.register_benchmark(
    "densenet121_l105", domain="CNN", paper_params=DENSENET,
    reduced_params=REDUCED, table2="(32 x 1152)x(1152 x 64)")
def build(m=32, k=1152, n=64, seed=0) -> common.Built:
    assert n % isa.VL_ELEMS == 0
    g = common.rng(seed)
    A = (g.standard_normal((m, k)) / np.sqrt(k)).astype(np.float32)
    B = (g.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)

    mm = MemoryMap()
    aa = mm.alloc("A", A)
    ab = mm.alloc("B", B)
    ac = mm.alloc("C", m * n)
    az = mm.alloc("zero", np.zeros(1, np.float32))

    a = Assembler("gemm")
    a.vbcast(ZR, az)
    chunks = n // isa.VL_ELEMS
    with a.repeat(m):                    # row loop: stride3 = per-row pitch
        with a.repeat(chunks):
            a.vmv(ACC, ZR)
            with a.repeat(k):
                a.vbcast(AR, aa, stride=4, stride2=0, stride3=k * 4)
                a.vle(BR, ab, stride=n * 4, stride2=32, stride3=0)
                a.vmacc(ACC, AR, BR)
            a.vse(ACC, ac, stride=32, stride2=n * 4)
            a.scalar(3)
        a.scalar(3)
    prog = a.finalize(mm)
    C = (A.astype(np.float64) @ B.astype(np.float64)).astype(np.float32)
    return common.Built(prog, {"C": C}, rtol=2e-4, atol=1e-5)


def scalar_cost(m=32, k=1152, n=64, **_) -> ScalarCost:
    macs = m * k * n
    # per MAC: lw b, fmadd (a kept in a scalar register per k step).
    return ScalarCost(flop_ops=macs, loads=macs + m * k, stores=m * n,
                      unique_lines=(m * k + k * n + m * n) // 8,
                      loop_iters=macs)
