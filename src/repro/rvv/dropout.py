"""Dropout (Table 2: vector length 131072, scale 0.5). ~3 active vregs."""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(n=131072, scale=0.5)
REDUCED = dict(n=512, scale=0.5)


@common.register_benchmark(
    "dropout", domain="ML", paper_params=PAPER, reduced_params=REDUCED,
    table2="Vector Length:131072 Scale:0.5")
def build(n=131072, scale=0.5, seed=0) -> common.Built:
    assert n % isa.VL_ELEMS == 0
    g = common.rng(seed)
    x = g.standard_normal(n).astype(np.float32)
    m = (g.random(n) < 0.5).astype(np.float32)   # precomputed binary mask

    mm = MemoryMap()
    ax = mm.alloc("x", x)
    am = mm.alloc("m", m)
    ay = mm.alloc("y", n)

    a = Assembler("dropout")
    with a.repeat(n // isa.VL_ELEMS):
        a.vle(1, ax, stride=32)
        a.vle(2, am, stride=32)
        a.vmul(3, 1, 2)
        a.vmul_sc(3, 3, scale)
        a.vse(3, ay, stride=32)
        a.scalar(3)                  # pointer bumps + branch
    prog = a.finalize(mm)
    expected = {"y": (x.astype(np.float64) * m * scale).astype(np.float32)}
    return common.Built(prog, expected)


def scalar_cost(n=131072, scale=0.5, **_) -> ScalarCost:
    # per element: lw x, lw m, fmul, fmul(scale), fsw + loop.
    return ScalarCost(flop_ops=2 * n, loads=2 * n, stores=n,
                      unique_lines=3 * n // 8, loop_iters=n)
