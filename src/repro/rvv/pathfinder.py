"""PathFinder (Table 2: 32x32 grid traversal DP). ~6 active vregs."""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(rows=32, cols=32)
REDUCED = dict(rows=8, cols=16)


def _padded(row: np.ndarray, width: int) -> np.ndarray:
    """[BIG, row..., BIG, align-pad] so j-1/j+1 reads are sentinel-guarded."""
    buf = np.full(width, common.BIG, np.float32)
    buf[1:1 + row.size] = row
    return buf


@common.register_benchmark(
    "pathfinder", domain="Grid Traversal", paper_params=PAPER,
    reduced_params=REDUCED, table2="Rows:32 Columns:32")
def build(rows=32, cols=32, seed=0) -> common.Built:
    assert cols % isa.VL_ELEMS == 0
    g = common.rng(seed)
    wall = g.integers(0, 10, (rows, cols)).astype(np.float32)
    width = cols + 2
    width += (-width) % isa.VL_ELEMS          # align each DP buffer

    mm = MemoryMap()
    awall = mm.alloc("wall", wall)
    bufs = [mm.alloc("buf0", _padded(wall[0], width)),
            mm.alloc("buf1", _padded(np.zeros(cols, np.float32), width))]

    a = Assembler("pathfinder")
    chunks = cols // isa.VL_ELEMS
    for i in range(1, rows):
        src = bufs[(i - 1) % 2]
        dst = bufs[i % 2]
        with a.repeat(chunks):
            a.vle(1, src + 0, stride=32)       # src[j-1] (aligned)
            a.vle(2, src + 4, stride=32)       # src[j]   (straddles lines)
            a.vle(3, src + 8, stride=32)       # src[j+1]
            a.vmin(4, 1, 2)
            a.vmin(4, 4, 3)
            a.vle(5, awall + i * cols * 4, stride=32)
            a.vadd(6, 4, 5)
            a.vse(6, dst + 4, stride=32)
            a.scalar(3)
        a.scalar(4)
    prog = a.finalize(mm)

    res = wall[0].astype(np.float64)
    for i in range(1, rows):
        pad = np.full(cols + 2, common.BIG, np.float64)
        pad[1:-1] = res
        res = wall[i] + np.minimum(np.minimum(pad[:-2], pad[1:-1]), pad[2:])
    final = _padded(np.zeros(cols, np.float32), width).astype(np.float64)
    final[1:1 + cols] = res
    name = "buf1" if (rows - 1) % 2 else "buf0"
    return common.Built(prog, {name: final.astype(np.float32)})


def scalar_cost(rows=32, cols=32, **_) -> ScalarCost:
    n = (rows - 1) * cols
    # per element: min3 = 2 compare+branch+mv sequences (branchy on an
    # in-order core: ~4 int ops each incl. flush), 1 add, 3 lw, 1 sw.
    return ScalarCost(int_ops=9 * n, loads=3 * n, stores=n,
                      unique_lines=rows * cols // 8 * 2, loop_iters=n)
