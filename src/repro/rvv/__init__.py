"""RVV-lite benchmark suite — the nine applications of the paper's Table 2
plus two beyond-paper deep-nest workloads (batched conv, multi-head
attention) exercising the per-level stride vectors of ``Assembler.repeat``.
"""

from __future__ import annotations

from repro.rvv import (common, conv2d, conv2d_batched, dropout,
                       flashattention2, gemm, gemv, jacobi2d, mha,
                       pathfinder, somier)
from repro.rvv.common import Benchmark, Built, check

BENCHMARKS: dict[str, Benchmark] = {
    "pathfinder": Benchmark(
        "pathfinder", "Grid Traversal", pathfinder.build,
        pathfinder.scalar_cost, pathfinder.PAPER, pathfinder.REDUCED,
        "Rows:32 Columns:32"),
    "jacobi2d": Benchmark(
        "jacobi2d", "Engineering", jacobi2d.build, jacobi2d.scalar_cost,
        jacobi2d.PAPER, jacobi2d.REDUCED, "Problem size:128 steps:10"),
    "somier": Benchmark(
        "somier", "Physics Simulation", somier.build, somier.scalar_cost,
        somier.PAPER, somier.REDUCED, "Problem size:32 steps:2"),
    "gemv": Benchmark(
        "gemv", "NLP", gemv.build, gemv.scalar_cost, gemv.PAPER,
        gemv.REDUCED, "(512 x 512) x 512"),
    "dropout": Benchmark(
        "dropout", "ML", dropout.build, dropout.scalar_cost, dropout.PAPER,
        dropout.REDUCED, "Vector Length:131072 Scale:0.5"),
    "conv2d_7x7": Benchmark(
        "conv2d_7x7", "CNN", conv2d.build, conv2d.scalar_cost, conv2d.PAPER,
        conv2d.REDUCED, "256 x 256 filter size:7"),
    "densenet121_l105": Benchmark(
        "densenet121_l105", "CNN", gemm.build, gemm.scalar_cost,
        gemm.DENSENET, gemm.REDUCED, "(32 x 1152)x(1152 x 64)"),
    "resnet50_l10": Benchmark(
        "resnet50_l10", "CNN", gemm.build, gemm.scalar_cost, gemm.RESNET,
        gemm.REDUCED, "(128 x 256)x(256 x 784)"),
    "flashattention2": Benchmark(
        "flashattention2", "Transformer", flashattention2.build,
        flashattention2.scalar_cost, flashattention2.PAPER,
        flashattention2.REDUCED,
        "Seq. Length:200 Hidden Dim.:64 Block row:1 Block col:128"),
    # Beyond-paper deep-nest workloads (4-level repeat nests; not in the
    # paper's Table 2/3 — the paper columns stay blank in reports).
    "conv2d_batched": Benchmark(
        "conv2d_batched", "CNN", conv2d_batched.build,
        conv2d_batched.scalar_cost, conv2d_batched.PAPER,
        conv2d_batched.REDUCED, "32 x 32 x2ch x8imgs filter size:3"),
    "mha": Benchmark(
        "mha", "Transformer", mha.build, mha.scalar_cost, mha.PAPER,
        mha.REDUCED, "Seq:40 Head Dim.:16 Heads:8"),
}

# The paper's Table 3 reference numbers, for side-by-side reporting.
PAPER_TABLE3 = {
    "pathfinder": dict(speedup=7.99, active_regs=6, util=0.18),
    "jacobi2d": dict(speedup=6.48, active_regs=7, util=0.21),
    "somier": dict(speedup=7.82, active_regs=14, util=0.44),
    "gemv": dict(speedup=6.89, active_regs=9, util=0.28),
    "dropout": dict(speedup=4.3, active_regs=3, util=0.09),
    "conv2d_7x7": dict(speedup=7.74, active_regs=15, util=0.47),
    "densenet121_l105": dict(speedup=7.82, active_regs=4, util=0.12),
    "resnet50_l10": dict(speedup=7.63, active_regs=4, util=0.12),
    "flashattention2": dict(speedup=7.91, active_regs=32, util=1.00),
}

__all__ = ["BENCHMARKS", "PAPER_TABLE3", "Benchmark", "Built", "check",
           "common", "conv2d", "conv2d_batched", "dropout",
           "flashattention2", "gemm", "gemv", "jacobi2d", "mha",
           "pathfinder", "somier"]
