"""RVV-lite benchmark suite — the nine applications of the paper's Table 2
plus two beyond-paper deep-nest workloads (batched conv, multi-head
attention) exercising the per-level stride vectors of ``Assembler.repeat``.

Kernels self-register via :func:`common.register_benchmark`; importing this
package populates :data:`BENCHMARKS` (a registry whose unknown-name lookups
raise with the sorted list of available kernels).  The import order below
fixes the registry iteration order to the paper's Table 2 sequence, with the
beyond-paper workloads last.
"""

from __future__ import annotations

# Table 2 order first (it is the registry's iteration order), then the
# beyond-paper deep-nest workloads.  Each import registers its kernels.
from repro.rvv import pathfinder     # noqa: F401  "pathfinder"
from repro.rvv import jacobi2d       # noqa: F401  "jacobi2d"
from repro.rvv import somier         # noqa: F401  "somier"
from repro.rvv import gemv           # noqa: F401  "gemv"
from repro.rvv import dropout       # noqa: F401  "dropout"
from repro.rvv import conv2d         # noqa: F401  "conv2d_7x7"
from repro.rvv import gemm           # noqa: F401  "densenet121_l105", "resnet50_l10"
from repro.rvv import flashattention2  # noqa: F401  "flashattention2"
from repro.rvv import conv2d_batched  # noqa: F401  "conv2d_batched"
from repro.rvv import mha            # noqa: F401  "mha"

from repro.rvv import common
from repro.rvv.common import (BENCHMARKS, Benchmark, Built, check,
                              get_benchmark, register_benchmark)

# The paper's Table 3 reference numbers, for side-by-side reporting.
PAPER_TABLE3 = {
    "pathfinder": dict(speedup=7.99, active_regs=6, util=0.18),
    "jacobi2d": dict(speedup=6.48, active_regs=7, util=0.21),
    "somier": dict(speedup=7.82, active_regs=14, util=0.44),
    "gemv": dict(speedup=6.89, active_regs=9, util=0.28),
    "dropout": dict(speedup=4.3, active_regs=3, util=0.09),
    "conv2d_7x7": dict(speedup=7.74, active_regs=15, util=0.47),
    "densenet121_l105": dict(speedup=7.82, active_regs=4, util=0.12),
    "resnet50_l10": dict(speedup=7.63, active_regs=4, util=0.12),
    "flashattention2": dict(speedup=7.91, active_regs=32, util=1.00),
}

__all__ = ["BENCHMARKS", "PAPER_TABLE3", "Benchmark", "Built", "check",
           "common", "conv2d", "conv2d_batched", "dropout",
           "flashattention2", "gemm", "gemv", "get_benchmark", "jacobi2d",
           "mha", "pathfinder", "register_benchmark", "somier"]
