"""Jacobi-2D (Table 2: problem size 128, 10 steps). ~7 active vregs.

The time loop ping-pongs between two grids, so consecutive steps touch
*different* buffers and no single emitted repeat block is periodic — but
the whole trace is periodic with period TWO steps.  ``core.folding``'s
state-snapshot pass detects that k = 2 super-period across the per-step
row-loop blocks and certifies the fold exact (the exact-outer plan keeps
warm-up + two full ping-pong periods and extrapolates the rest
bit-identically)."""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(n=128, steps=10)
REDUCED = dict(n=16, steps=2)


def _stride_words(n: int) -> int:
    w = n + 2
    w += (-w) % isa.VL_ELEMS
    return w


@common.register_benchmark(
    "jacobi2d", domain="Engineering", paper_params=PAPER,
    reduced_params=REDUCED, table2="Problem size:128 steps:10")
def build(n=128, steps=10, seed=0) -> common.Built:
    assert n % isa.VL_ELEMS == 0
    g = common.rng(seed)
    w = _stride_words(n)                     # padded row width (words)
    grid = np.zeros((n + 2, w), np.float32)
    grid[1:n + 1, 1:n + 1] = g.random((n, n), dtype=np.float32)

    mm = MemoryMap()
    a0 = mm.alloc("g0", grid)
    a1 = mm.alloc("g1", grid)                # ping-pong copy (halo included)
    rs = w * 4                               # row stride in bytes

    a = Assembler("jacobi2d")
    chunks = n // isa.VL_ELEMS
    for s in range(steps):
        src = (a0, a1)[s % 2]
        dst = (a0, a1)[(s + 1) % 2]
        with a.repeat(n):                            # grid rows: stride2 = rs
            r = src + rs                             # first interior row
            with a.repeat(chunks):
                a.vle(1, r - rs + 4, stride=32, stride2=rs)     # up
                a.vle(2, r + rs + 4, stride=32, stride2=rs)     # down
                a.vle(3, r + 0, stride=32, stride2=rs)          # left
                a.vle(4, r + 8, stride=32, stride2=rs)          # right
                a.vle(5, r + 4, stride=32, stride2=rs)          # center
                a.vadd(6, 1, 2)
                a.vadd(6, 6, 3)
                a.vadd(6, 6, 4)
                a.vadd(6, 6, 5)
                a.vmul_sc(6, 6, 0.2)
                a.vse(6, dst + rs + 4, stride=32, stride2=rs)
                a.scalar(3)
            a.scalar(4)
    prog = a.finalize(mm)

    # f64 mirror with identical association order.
    ref = grid.astype(np.float64)
    buf = ref.copy()
    for _ in range(steps):
        up = ref[0:n, 1:n + 1]
        dn = ref[2:n + 2, 1:n + 1]
        lf = ref[1:n + 1, 0:n]
        rt = ref[1:n + 1, 2:n + 2]
        ct = ref[1:n + 1, 1:n + 1]
        buf = ref.copy()
        buf[1:n + 1, 1:n + 1] = (((up + dn) + lf) + rt + ct) * 0.2
        ref, buf = buf, ref
    final = ref                                 # after `steps` swaps
    name = ("g0", "g1")[steps % 2]
    return common.Built(prog, {name: final.astype(np.float32)},
                        rtol=1e-4, atol=1e-6)


def scalar_cost(n=128, steps=10, **_) -> ScalarCost:
    pts = steps * n * n
    # per point: 4 fadd + 1 fmul + 5 lw (2 forwarded across j) + 1 sw.
    return ScalarCost(flop_ops=5 * pts, loads=3 * pts, stores=pts,
                      unique_lines=steps * (n * _stride_words(n) // 8),
                      loop_iters=pts)
