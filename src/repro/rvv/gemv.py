"""GemV (Table 2, NLP: (512x512) x 512). Two-row unrolled; ~8 active vregs."""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(m=512, k=512)
REDUCED = dict(m=16, k=32)

Z = 31     # register holding broadcast 0.0


@common.register_benchmark(
    "gemv", domain="NLP", paper_params=PAPER, reduced_params=REDUCED,
    table2="(512 x 512) x 512")
def build(m=512, k=512, seed=0) -> common.Built:
    assert k % isa.VL_ELEMS == 0 and m % 2 == 0
    g = common.rng(seed)
    A = g.standard_normal((m, k)).astype(np.float32) / np.sqrt(k)
    x = g.standard_normal(k).astype(np.float32)

    mm = MemoryMap()
    aA = mm.alloc("A", A)
    ax = mm.alloc("x", x)
    ay = mm.alloc("y", m)
    az = mm.alloc("zero", np.zeros(1, np.float32))

    a = Assembler("gemv")
    a.vbcast(Z, az)
    with a.repeat(m // 2):               # row-pair loop
        a.vmv(4, Z)                  # acc0 = 0
        a.vmv(5, Z)                  # acc1 = 0
        with a.repeat(k // isa.VL_ELEMS):
            a.vle(1, ax, stride=32, stride2=0)
            a.vle(2, aA, stride=32, stride2=2 * k * 4)
            a.vmacc(4, 1, 2)
            a.vle(3, aA + k * 4, stride=32, stride2=2 * k * 4)
            a.vmacc(5, 1, 3)
            a.scalar(3)
        a.vredsum(6, Z, 4)
        a.vses(6, ay, stride=8)
        a.vredsum(6, Z, 5)
        a.vses(6, ay + 4, stride=8)
        a.scalar(4)
    prog = a.finalize(mm)
    y = (A.astype(np.float64) @ x.astype(np.float64)).astype(np.float32)
    return common.Built(prog, {"y": y})


def scalar_cost(m=512, k=512, **_) -> ScalarCost:
    # per (i,k): lw a, lw x (x L1-resident), fmadd + loop.
    n = m * k
    return ScalarCost(flop_ops=n, loads=2 * n, stores=m,
                      unique_lines=n // 8 + k // 8, loop_iters=n)
