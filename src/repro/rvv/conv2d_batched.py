"""Batched multi-channel 2-D convolution (beyond-paper CNN workload).

A four-deep loop nest — batch x output-row-pair x column-chunk x input
channel — that the three fixed stride levels of the old ``Assembler.repeat``
could not express: the input loads advance along FOUR axes (channel plane,
chunk, row pitch, batch image), exercising the general per-level stride
vector.  Structure follows ``rvv.conv2d`` (two output rows per pass share
the broadcast weights); the channel loop accumulates into the same ACC
registers across planes.

Every batch image is padded to a whole number of L1 way-spans (8 KB), so
consecutive batch iterations touch the *same* cache sets and the batch loop
reaches a translation-invariant steady state the periodic-folding engine
can certify exact (warm-up + two measured images, rest extrapolated).
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common
from repro.rvv.conv2d import ACC0, ACC1, ZR, emit_taps

PAPER = dict(n=32, f=3, batch=8, cin=2)
REDUCED = dict(n=16, f=3, batch=2, cin=2)

# Plane pitch: pad each (channel or output) plane to a whole number of L1
# way-spans so the batch-axis address translation is set-congruent.
_WAY_SPAN_WORDS = 2048            # 8 KB / 4-byte words (256 sets x 32 B)


def _plane_words(n: int) -> int:
    need = n * n + 64             # + overhang for the last column chunk
    return -(-need // _WAY_SPAN_WORDS) * _WAY_SPAN_WORDS


@common.register_benchmark(
    "conv2d_batched", domain="CNN", paper_params=PAPER,
    reduced_params=REDUCED, table2="32 x 32 x2ch x8imgs filter size:3")
def build(n=32, f=3, batch=8, cin=2, seed=0) -> common.Built:
    g = common.rng(seed)
    out_n = n - f + 1
    assert out_n % 2 == 0
    chunks = (out_n + isa.VL_ELEMS - 1) // isa.VL_ELEMS
    pw = _plane_words(n)

    img = g.standard_normal((batch, cin, n, n)).astype(np.float32)
    w = (g.standard_normal((cin, f, f)) / f).astype(np.float32)
    img_pad = np.zeros((batch, cin, pw), np.float32)
    img_pad[:, :, : n * n] = img.reshape(batch, cin, n * n)

    mm = MemoryMap()
    ai = mm.alloc("img", img_pad)
    aw = mm.alloc("w", w)
    aos = [mm.alloc(f"out{b}", pw) for b in range(batch)]
    az = mm.alloc("zero", np.zeros(1, np.float32))

    rs = n * 4                    # input row stride (bytes)
    chan = pw * 4                 # channel-plane pitch (bytes)
    bimg = cin * pw * 4           # batch-image pitch (bytes)
    bout = aos[1] - aos[0] if batch > 1 else 0

    a = Assembler("conv2d_batched")
    a.vbcast(ZR, az)
    with a.repeat(batch):                        # L3: batch image
        with a.repeat(out_n // 2):               # L2: row-pair pitch
            with a.repeat(chunks):               # L1: column chunk
                a.vmv(ACC0, ZR)
                a.vmv(ACC1, ZR)
                with a.repeat(cin):              # L0: channel plane
                    for fr in range(f):
                        emit_taps(a, ai, aw, fr, f, rs,
                                  in_strides=(chan, 32, 2 * rs, bimg),
                                  w_strides=(f * f * 4,))
                a.vse(ACC0, aos[0], strides=(32, 2 * rs, bout))
                a.vse(ACC1, aos[0] + rs, strides=(32, 2 * rs, bout))
                a.scalar(4)
            a.scalar(4)
        a.scalar(2)
    prog = a.finalize(mm)

    # f64 mirror (same channel-then-fr-then-fc accumulation order).
    I = img.astype(np.float64)
    regions = {}
    for b in range(batch):
        ref = np.zeros((out_n, out_n))
        for c in range(cin):
            for fr in range(f):
                for fc in range(f):
                    ref += (I[b, c, fr:fr + out_n, fc:fc + out_n]
                            * float(w[c, fr, fc]))
        regions[f"out{b}"] = (ref.astype(np.float32), n)
    return common.Built(prog, {}, rtol=2e-4, atol=1e-5, regions=regions)


def scalar_cost(n=32, f=3, batch=8, cin=2, **_) -> ScalarCost:
    out_n = n - f + 1
    taps = batch * cin * out_n * out_n * f * f
    return ScalarCost(flop_ops=taps, loads=taps,
                      stores=batch * out_n * out_n,
                      unique_lines=batch * cin * n * n // 8,
                      loop_iters=taps // f)
