"""fconv2d-7x7 (Table 2: 256x256 input, 7x7 filter, valid). ~13 vregs.

Two output rows are computed per pass sharing the broadcast filter weights
(the "strategic grouping and unrolling of vector register names" the paper
credits for fconv2d's resilience, Fig 6 discussion).

The tap-loop emission (:func:`emit_taps`) is shared with the batched
multi-channel variant (``rvv.conv2d_batched``), which wraps it in channel
and batch repeats using the per-level stride vectors of
``Assembler.repeat``.
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(n=256, f=7)
REDUCED = dict(n=32, f=7)

ACC0, ACC1, IN0, IN1 = 1, 2, 3, 4
W = list(range(9, 16))          # v9..v15 hold one filter row
ZR = 31


def emit_taps(a: Assembler, ai: int, aw: int, fr: int, f: int, rs: int,
              in_strides: tuple, w_strides: tuple = ()) -> None:
    """One filter row of the two-output-row conv body: broadcast the f
    weights of filter row ``fr``, then accumulate the f taps into ACC0/ACC1.

    ``in_strides``/``w_strides`` are per-level stride vectors for the input
    loads and weight broadcasts (the enclosing repeats decide how many
    levels are live: chunk, row-pair, channel, batch).
    """
    for fc in range(f):
        a.vbcast(W[fc], aw + (fr * f + fc) * 4, strides=w_strides)
    for fc in range(f):
        a.vle(IN0, ai + fr * rs + fc * 4, strides=in_strides)
        a.vmacc(ACC0, IN0, W[fc])
        a.vle(IN1, ai + (1 + fr) * rs + fc * 4, strides=in_strides)
        a.vmacc(ACC1, IN1, W[fc])


@common.register_benchmark(
    "conv2d_7x7", domain="CNN", paper_params=PAPER, reduced_params=REDUCED,
    table2="256 x 256 filter size:7")
def build(n=256, f=7, seed=0) -> common.Built:
    g = common.rng(seed)
    img = g.standard_normal((n, n)).astype(np.float32)
    w = (g.standard_normal((f, f)) / f).astype(np.float32)
    out_n = n - f + 1
    chunks = (out_n + isa.VL_ELEMS - 1) // isa.VL_ELEMS
    assert out_n % 2 == 0

    mm = MemoryMap()
    ai = mm.alloc("img", img)
    aw = mm.alloc("w", w)
    ao = mm.alloc("out", n * n + 64)      # padded: last chunk writes overhang
    az = mm.alloc("zero", np.zeros(1, np.float32))

    rs = n * 4
    a = Assembler("conv2d")
    a.vbcast(ZR, az)
    with a.repeat(out_n // 2):                   # row-pair loop: 2*rs pitch
        with a.repeat(chunks):
            a.vmv(ACC0, ZR)
            a.vmv(ACC1, ZR)
            for fr in range(f):
                emit_taps(a, ai, aw, fr, f, rs, in_strides=(32, 2 * rs))
            a.vse(ACC0, ao, stride=32, stride2=2 * rs)
            a.vse(ACC1, ao + rs, stride=32, stride2=2 * rs)
            a.scalar(4)
        a.scalar(4)
    prog = a.finalize(mm)

    # f64 mirror (same fr-then-fc accumulation order).
    ref = np.zeros((out_n, out_n))
    I = img.astype(np.float64)
    for fr in range(f):
        for fc in range(f):
            ref += I[fr:fr + out_n, fc:fc + out_n] * float(w[fr, fc])
    # Compare only the valid region of each padded output row.
    want = ref.astype(np.float32)
    return common.Built(prog, {}, rtol=2e-4, atol=1e-5,
                        regions={"out": (want, n)})


def scalar_cost(n=256, f=7, **_) -> ScalarCost:
    out_n = n - f + 1
    taps = out_n * out_n * f * f
    # per tap: lw + fmadd (weights in regs); the 7-tap fc loop is unrolled
    # by the compiler, leaving per-(pixel, filter-row) overhead.
    return ScalarCost(flop_ops=taps, loads=taps, stores=out_n * out_n,
                      unique_lines=n * n // 8, loop_iters=taps // f)
