"""Multi-head self-attention (beyond-paper Transformer workload).

Wraps the FlashAttention-2 emission core (``rvv.flashattention2``) in a
head ``repeat``: every Q/KT/V/O access gains a FOURTH per-level stride (the
head-plane pitch) on top of its own loop levels — broadcast-within-dot (4),
KT column walk, row-group advance — which the old fixed three-level
``Assembler.repeat`` could not express.  The online-softmax scratch (S, m,
l, acc) is shared across heads, exactly as a single-core RVV implementation
would reuse its scratch.

Register names rotate through v1..v30 across query rows and phases (the
paper's Table 3 full-utilisation property), so the per-head instruction
block is identical and the head loop is a clean candidate for periodic
folding: head planes are padded to whole L1 way-spans (8 KB) so consecutive
heads touch the same cache sets, and the folding engine certifies the head
loop exact (warm-up + two measured heads, rest extrapolated).
"""

from __future__ import annotations

import numpy as np

from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common
from repro.rvv.flashattention2 import (VL, emit_attention,
                                       reference_attention, scratch_buffers)

PAPER = dict(seq=40, d=16, bc=40, heads=8)
REDUCED = dict(seq=16, d=16, bc=16, heads=2)

# Head-plane pitch: pad every per-head Q/KT/V/O plane to a whole number of
# L1 way-spans so the head-axis address translation is set-congruent.
_WAY_SPAN_WORDS = 2048            # 8 KB / 4-byte words (256 sets x 32 B)


def _plane_words(seq: int, d: int) -> int:
    return -(-(seq * d) // _WAY_SPAN_WORDS) * _WAY_SPAN_WORDS


@common.register_benchmark(
    "mha", domain="Transformer", paper_params=PAPER, reduced_params=REDUCED,
    table2="Seq:40 Head Dim.:16 Heads:8")
def build(seq=40, d=16, bc=40, heads=8, seed=0) -> common.Built:
    assert seq % VL == 0 and d % VL == 0 and bc % VL == 0
    g = common.rng(seed)
    pw = _plane_words(seq, d)
    Q = (g.standard_normal((heads, seq, d)) * 0.3).astype(np.float32)
    K = (g.standard_normal((heads, seq, d)) * 0.3).astype(np.float32)
    V = g.standard_normal((heads, seq, d)).astype(np.float32)

    def planes(x):                      # (H, seq*d) -> (H, pw) padded planes
        out = np.zeros((heads, pw), np.float32)
        out[:, : seq * d] = x.reshape(heads, seq * d)
        return out

    KT = np.stack([np.ascontiguousarray(K[h].T) for h in range(heads)])
    mm = MemoryMap()
    bufs = dict(
        aq=mm.alloc("Q", planes(Q)),
        akt=mm.alloc("KT", planes(KT)),
        av=mm.alloc("V", planes(V)),
        ao=mm.alloc("O", heads * pw),
    )
    bufs.update(scratch_buffers(mm, seq, d))
    adv = pw * 4                        # head-plane pitch (bytes)

    a = Assembler("mha")
    with a.repeat(heads):
        emit_attention(a, bufs, seq, d, bc,
                       head_advs=dict(q=adv, kt=adv, v=adv, o=adv))
    prog = a.finalize(mm)

    O = np.zeros((heads, pw), np.float32)
    for h in range(heads):
        O[h, : seq * d] = reference_attention(
            Q[h], K[h], V[h], bc).astype(np.float32).reshape(-1)
    return common.Built(prog, {"O": O}, rtol=5e-3, atol=1e-4)


def scalar_cost(seq=40, d=16, heads=8, **_) -> ScalarCost:
    # per head: scores + PV MACs, plus the scalar-softmax exp cost
    # (~25 flop-equivalents per element), as in flashattention2.
    macs = heads * 2 * seq * seq * d
    sm = 25 * heads * seq * seq
    return ScalarCost(flop_ops=macs + sm,
                      loads=macs + 2 * heads * seq * seq,
                      stores=heads * (seq * d + 2 * seq * seq),
                      unique_lines=heads * (3 * seq * d) // 8,
                      loop_iters=macs)
