"""Somier (Table 2: 3-D spring-mass physics, size 32, 2 steps). ~14 vregs.

Per time step: force accumulation over the 6 grid neighbours (spring model
with sqrt-normalised direction, like RiVEC's somier), then velocity/position
integration.  Vectorised along z.

Folding stays honestly *uncertified* for this kernel: its steady state
spans a whole time step (force + integrate share the pos/vel/frc arrays at
different line rates, so cross-period reuse gaps inside the i-row loops are
non-stationary), and with only 2 paper-size steps the step-level period
detector never sees the >= 4 repetitions it needs to detect a stable
super-period — there is nothing to extrapolate.  See docs/folding.md.
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(n=32, steps=2)
REDUCED = dict(n=8, steps=1)

K_SPRING = 0.4
L0 = 0.9
DT = 0.001

# registers
CX, CY, CZ = 1, 2, 3          # centre position
NX, NY, NZ = 4, 5, 6          # neighbour position
DX, DY, DZ = 7, 8, 9          # displacement
T1, T2 = 10, 11               # dist^2 / dist / coef temporaries
FX, FY, FZ = 12, 13, 14       # force accumulators
ZR = 31                       # broadcast zero
DTR = 30                      # broadcast dt


def _zpad(n: int) -> int:
    z = n + 2
    z += (-z) % isa.VL_ELEMS
    return z


@common.register_benchmark(
    "somier", domain="Physics Simulation", paper_params=PAPER,
    reduced_params=REDUCED, table2="Problem size:32 steps:2")
def build(n=32, steps=2, seed=0) -> common.Built:
    assert n % isa.VL_ELEMS == 0
    g = common.rng(seed)
    zp = _zpad(n)
    shape = (n + 2, n + 2, zp)
    pos = np.zeros((3,) + shape, np.float32)
    # Slightly perturbed lattice; halo equals the lattice so that edge springs
    # have near-rest length.
    ii = np.arange(n + 2)[:, None, None]
    jj = np.arange(n + 2)[None, :, None]
    kk = np.arange(zp)[None, None, :]
    base = np.stack([ii + 0 * jj + 0 * kk, jj + 0 * ii + 0 * kk,
                     kk + 0 * ii + 0 * jj]).astype(np.float32)
    pos = base.copy()
    pos[:, 1:n + 1, 1:n + 1, 1:n + 1] += (
        0.1 * g.standard_normal((3, n, n, n)).astype(np.float32))
    vel = np.zeros_like(pos)
    frc = np.zeros_like(pos)

    mm = MemoryMap()
    ap = [mm.alloc(f"pos{c}", pos[i]) for i, c in enumerate("xyz")]
    av = [mm.alloc(f"vel{c}", vel[i]) for i, c in enumerate("xyz")]
    af = [mm.alloc(f"frc{c}", frc[i]) for i, c in enumerate("xyz")]
    az = mm.alloc("zero", np.zeros(1, np.float32))
    adt = mm.alloc("dt", np.full(1, DT, np.float32))

    ys = zp * 4                       # byte stride along y
    xs = (n + 2) * ys                 # byte stride along x
    nbr_off = [xs, -xs, ys, -ys, 4, -4]
    chunks = n // isa.VL_ELEMS

    a = Assembler("somier")
    a.vbcast(ZR, az)
    a.vbcast(DTR, adt)
    off = xs + ys + 4                 # (i=1, j=1, k=1) start (unaligned)
    for _ in range(steps):
        # ---------------- force pass ----------------
        with a.repeat(n):                          # i rows:    stride3 = xs
            with a.repeat(n):                      # j columns: stride2 = ys
                with a.repeat(chunks):
                    a.vmv(FX, ZR); a.vmv(FY, ZR); a.vmv(FZ, ZR)
                    a.vle(CX, ap[0] + off, stride=32, stride2=ys, stride3=xs)
                    a.vle(CY, ap[1] + off, stride=32, stride2=ys, stride3=xs)
                    a.vle(CZ, ap[2] + off, stride=32, stride2=ys, stride3=xs)
                    for d in nbr_off:
                        a.vle(NX, ap[0] + off + d, stride=32, stride2=ys,
                              stride3=xs)
                        a.vle(NY, ap[1] + off + d, stride=32, stride2=ys,
                              stride3=xs)
                        a.vle(NZ, ap[2] + off + d, stride=32, stride2=ys,
                              stride3=xs)
                        a.vsub(DX, NX, CX)
                        a.vsub(DY, NY, CY)
                        a.vsub(DZ, NZ, CZ)
                        a.vmul(T1, DX, DX)
                        a.vmacc(T1, DY, DY)
                        a.vmacc(T1, DZ, DZ)
                        a.vsqrt(T1, T1)            # dist
                        a.vadd_sc(T2, T1, -L0)     # dist - L0
                        a.vmul_sc(T2, T2, K_SPRING)
                        a.vdiv(T2, T2, T1)         # K*(dist-L0)/dist
                        a.vmacc(FX, T2, DX)
                        a.vmacc(FY, T2, DY)
                        a.vmacc(FZ, T2, DZ)
                    a.vse(FX, af[0] + off, stride=32, stride2=ys, stride3=xs)
                    a.vse(FY, af[1] + off, stride=32, stride2=ys, stride3=xs)
                    a.vse(FZ, af[2] + off, stride=32, stride2=ys, stride3=xs)
                    a.scalar(4)
                a.scalar(3)
        # ---------------- integrate pass ----------------
        with a.repeat(n):
            with a.repeat(n):
                with a.repeat(chunks):
                    for c in range(3):
                        a.vle(1, af[c] + off, stride=32, stride2=ys,
                              stride3=xs)                    # F
                        a.vle(2, av[c] + off, stride=32, stride2=ys,
                              stride3=xs)                    # v
                        a.vmacc(2, DTR, 1)                   # v += dt*F
                        a.vse(2, av[c] + off, stride=32, stride2=ys,
                              stride3=xs)
                        a.vle(3, ap[c] + off, stride=32, stride2=ys,
                              stride3=xs)                    # p
                        a.vmacc(3, DTR, 2)                   # p += dt*v
                        a.vse(3, ap[c] + off, stride=32, stride2=ys,
                              stride3=xs)
                    a.scalar(4)
                a.scalar(3)
    prog = a.finalize(mm)

    # ------------------- f64 mirror -------------------
    P = pos.astype(np.float64)
    V = vel.astype(np.float64)
    F = frc.astype(np.float64)
    sl = (slice(1, n + 1), slice(1, n + 1), slice(1, n + 1))

    def shifted(A, d):
        ax = {0: (1, 0, 0), 1: (-1, 0, 0), 2: (0, 1, 0),
              3: (0, -1, 0), 4: (0, 0, 1), 5: (0, 0, -1)}[d]
        return A[:, 1 + ax[0]:n + 1 + ax[0], 1 + ax[1]:n + 1 + ax[1],
                 1 + ax[2]:n + 1 + ax[2]]

    for _ in range(steps):
        acc = np.zeros((3, n, n, n))
        for d in range(6):
            diff = shifted(P, d) - P[:, sl[0], sl[1], sl[2]]
            dist = np.sqrt((diff ** 2).sum(axis=0))
            coef = K_SPRING * (dist - L0) / dist
            acc += coef * diff
        F[:, sl[0], sl[1], sl[2]] = acc
        V[:, sl[0], sl[1], sl[2]] += DT * F[:, sl[0], sl[1], sl[2]]
        P[:, sl[0], sl[1], sl[2]] += DT * V[:, sl[0], sl[1], sl[2]]

    expected = {}
    for i, c in enumerate("xyz"):
        expected[f"pos{c}"] = P[i].astype(np.float32)
        expected[f"vel{c}"] = V[i].astype(np.float32)
    return common.Built(prog, expected, rtol=2e-4, atol=1e-5)


def scalar_cost(n=32, steps=2, **_) -> ScalarCost:
    pts = steps * n ** 3
    # per point per neighbour: 9 flops + fsqrt(~12cyc=6 flop-equiv) +
    # fdiv(~12) + 3 lw; plus integration (6 flops, 9 mem ops).
    return ScalarCost(flop_ops=pts * (6 * 21 + 6),
                      loads=pts * (6 * 3 + 6), stores=pts * 9,
                      unique_lines=steps * 9 * n * n * _zpad(n) // 8,
                      loop_iters=pts * 2)
