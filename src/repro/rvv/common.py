"""Shared helpers for the RVV-lite benchmark kernels (paper Table 2)."""

from __future__ import annotations

import dataclasses
import sys
from typing import Callable

import numpy as np

from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap, Program

BIG = np.float32(1e30)


@dataclasses.dataclass
class Built:
    """A built benchmark: the trace plus its expected outputs.

    ``expected`` maps buffer name -> expected contents; ``regions`` maps
    buffer name -> (expected 2-D array, (rows, row_stride_words)) for kernels
    whose valid output is a sub-rectangle of a padded buffer.
    """

    program: Program
    expected: dict[str, np.ndarray]   # buffer name -> expected final contents
    rtol: float = 1e-4                # reference computed in f64; trace is f32
    atol: float = 1e-5
    regions: dict[str, tuple[np.ndarray, int]] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class Benchmark:
    name: str
    domain: str
    build: Callable[..., Built]
    scalar_cost: Callable[..., ScalarCost]
    paper_params: dict
    reduced_params: dict
    table2: str = ""                   # the paper's Table 2 description


class BenchmarkRegistry(dict):
    """``name -> Benchmark`` map populated by :func:`register_benchmark`.

    Unknown lookups raise with the sorted list of registered kernels, so a
    typo'd sweep axis fails with the menu instead of a bare KeyError.
    """

    def __missing__(self, name):
        raise KeyError(
            f"unknown kernel {name!r}; available: "
            f"{', '.join(sorted(self))}")


BENCHMARKS: BenchmarkRegistry = BenchmarkRegistry()


def register_benchmark(name: str, *, domain: str, paper_params: dict,
                       reduced_params: dict, table2: str = "",
                       scalar_cost: Callable[..., ScalarCost] | None = None,
                       exist_ok: bool = False):
    """Decorator registering a kernel's ``build`` function as a Benchmark.

    ``scalar_cost`` defaults to the decorated module's ``scalar_cost``
    function, resolved lazily (kernel modules conventionally define it below
    ``build``).  A module may stack the decorator to register several named
    configurations of one build function (see ``rvv.gemm``).

    ``exist_ok=True`` makes re-registration of the same name idempotent (the
    first registration wins); the trace-from-model bridge uses this so that
    lowering the same network twice — or two networks sharing a layer shape —
    does not raise.  Hand-written kernels keep the default duplicate check.
    """
    def deco(build: Callable[..., Built]) -> Callable[..., Built]:
        cost = scalar_cost
        if cost is None:
            mod = sys.modules[build.__module__]
            cost = lambda **kw: mod.scalar_cost(**kw)  # noqa: E731
        if name in BENCHMARKS:
            if exist_ok:
                return build
            raise ValueError(f"benchmark {name!r} registered twice")
        BENCHMARKS[name] = Benchmark(name, domain, build, cost,
                                     dict(paper_params), dict(reduced_params),
                                     table2)
        return build
    return deco


def get_benchmark(name: str) -> Benchmark:
    """Registry lookup; unknown names raise with the available kernels."""
    return BENCHMARKS[name]


def rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def check(built: Built, memory: np.ndarray) -> None:
    """Assert every expected buffer matches the interpreter's final memory."""
    for name, want in built.expected.items():
        got = built.program.buffer_view(memory, name)[: want.size]
        np.testing.assert_allclose(
            got, want.reshape(-1), rtol=built.rtol, atol=built.atol,
            err_msg=f"buffer {name!r} mismatch")
    for name, (want2d, stride_words) in built.regions.items():
        r, cwid = want2d.shape
        got = built.program.buffer_view(memory, name)
        got2d = got[: r * stride_words].reshape(r, stride_words)[:, :cwid]
        np.testing.assert_allclose(
            got2d, want2d, rtol=built.rtol, atol=built.atol,
            err_msg=f"buffer region {name!r} mismatch")


# ------------------------------------------------------------------ exp ----
# Vectorised exp approximation used by FlashAttention-2: RVV has no exp
# instruction, so real kernels use a short polynomial / squaring scheme.
# exp(x) ~= (1 + clamp(x, -60, 0)/32)**32  (monotone, strictly positive, and
# identical in the trace and the numpy reference).

EXP_SQUARINGS = 5
EXP_DENOM = float(2 ** EXP_SQUARINGS)
EXP_CLAMP = -60.0


def emit_exp(a: Assembler, r: int, r_clamp: int) -> None:
    """In-place exp approximation of register ``r``; ``r_clamp`` must hold
    broadcast EXP_CLAMP. Exercises the v0 mask path (vmslt + vmerge)."""
    a.vmslt(r, r_clamp)            # v0 = (x < -60)
    a.vmerge(r, r_clamp, r)        # x = v0 ? -60 : x
    a.vmul_sc(r, r, 1.0 / EXP_DENOM)
    a.vadd_sc(r, r, 1.0)
    for _ in range(EXP_SQUARINGS):
        a.vmul(r, r, r)


def np_exp_approx(x: np.ndarray) -> np.ndarray:
    x = np.maximum(x, EXP_CLAMP)
    t = 1.0 + x / EXP_DENOM
    for _ in range(EXP_SQUARINGS):
        t = t * t
    return t
