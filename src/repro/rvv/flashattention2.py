"""FlashAttention-2 self-attention layer (Table 2: seq 200, hidden 64,
Br=1, Bc=128) vectorised for RVV, as in the paper's BERT benchmark.

Key property reproduced from the paper (Table 3 + Fig 5): the kernel touches
ALL 32 architectural vector registers over its execution — register names
rotate across query rows and phases, as a compiler allocates fresh names
across unrolled phases — yet each phase's instantaneous working set is ~3
registers, so a cVRF of only 3 entries already achieves a >95% hit rate.

Online-softmax state (running max m, normaliser l, output accumulator) is
memory-resident and round-trips through scratch (vredmax/vses + vbcast), as
real RVV code moves lane-0 scalars; exp() is the shared squaring
approximation from ``rvv.common`` (identical in trace and reference).
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common

PAPER = dict(seq=200, d=64, bc=128)
REDUCED = dict(seq=16, d=16, bc=8)

NEG = -1e9
VL = isa.VL_ELEMS


def _rot(i: int) -> int:
    """Rotating register base: phases cycle through v1..v30 in groups of 3."""
    return 1 + 3 * (i % 10)


def scratch_buffers(mm: MemoryMap, seq: int, d: int) -> dict:
    """Online-softmax scratch shared by FA-2 and the multi-head kernel."""
    return dict(
        aS=mm.alloc("S", seq),          # score/prob row scratch
        am=mm.alloc("m", VL),           # running max (all lanes)
        amold=mm.alloc("mold", VL),     # previous running max
        al=mm.alloc("l", VL),           # normaliser (all lanes)
        asum=mm.alloc("psum", VL),      # block prob-sum scratch
        aacc=mm.alloc("acc", d),        # output accumulator scratch
        az=mm.alloc("zero", np.zeros(1, np.float32)),
        an=mm.alloc("neginf", np.full(1, NEG, np.float32)),
        ac=mm.alloc("clamp", np.full(1, common.EXP_CLAMP, np.float32)),
    )


def emit_attention(a: Assembler, bufs: dict, seq: int, d: int, bc: int,
                   head_advs: dict | None = None) -> None:
    """Emit one full FlashAttention-2 pass over ``seq`` query rows.

    ``bufs`` holds the Q/KT/V/O base addresses plus the scratch from
    :func:`scratch_buffers`.  ``head_advs`` (keys ``q``/``kt``/``v``/``o``)
    appends one more per-level stride to every Q/KT/V/O access so an
    enclosing head ``repeat`` advances the planes — the multi-head kernel's
    fourth stride level.  With ``head_advs=None`` the emission is exactly
    the single-head FA-2 trace.
    """
    aq, akt, av, ao = bufs["aq"], bufs["akt"], bufs["av"], bufs["ao"]
    aS, am, amold = bufs["aS"], bufs["am"], bufs["amold"]
    al, asum, aacc = bufs["al"], bufs["asum"], bufs["aacc"]
    az, an, ac = bufs["az"], bufs["an"], bufs["ac"]
    scale = 1.0 / np.sqrt(d)
    dc = d // VL                               # output chunks per row
    n_blocks = (seq + bc - 1) // bc
    # The register rotation has period 10, so 10 consecutive query rows form
    # one periodic block: emit them inside a repeat (with the per-group Q/O
    # advance as the outermost stride) so the trace carries fold metadata.
    group = 10 if seq % 10 == 0 else 1
    grp_adv = group * d * 4 if group > 1 else 0

    def sfx(grp_stride, head_key):
        """Outer stride levels beyond a Q/KT/V/O access's own loops: the
        row-group advance (when grouped) then the head-plane advance."""
        t = (grp_stride,) if group > 1 else ()
        return t + ((head_advs[head_key],) if head_advs else ())

    def emit_row(i):
        # ---- row init: acc = 0, m = -inf, l = 0 (memory-resident state)
        a.vbcast(31, az)
        with a.repeat(dc):
            a.vse(31, aacc, stride=32)
        a.vbcast(30, an)
        a.vse(30, am)
        a.vse(31, al)
        a.scalar(2)

        for b in range(n_blocks):
            j0 = b * bc
            jn = min(bc, seq - j0)
            bchunks = jn // VL

            # ---- phase 1: s[j] = scale * (q_i . k_j), vectorised over j
            r0, r1, r2 = (_rot(i) + k for k in range(3))
            with a.repeat(bchunks):
                a.vbcast(r0, az)
                with a.repeat(d):
                    a.vbcast(r1, aq + i * d * 4,
                             strides=(4, 0) + sfx(grp_adv, "q"))
                    a.vle(r2, akt + j0 * 4,
                          strides=(seq * 4, 32) + sfx(0, "kt"))
                    a.vmacc(r0, r1, r2)
                a.vmul_sc(r0, r0, scale)
                a.vse(r0, aS + j0 * 4, stride=32)
                a.scalar(3)

            # ---- phase 2: m_old save + block running max
            m0, m1, _ = (_rot(i + 3) + k for k in range(3))
            a.vle(m0, am)
            a.vse(m0, amold)                   # save m_old
            with a.repeat(bchunks):
                a.vle(m1, aS + j0 * 4, stride=32)
                a.vredmax(m0, m0, m1)          # m0[0] accumulates block max
                a.scalar(1)
            a.vses(m0, am)
            a.vbcast(m0, am)                   # all lanes = m_new
            a.vse(m0, am)                      # keep invariant: am broadcast

            # ---- phase 3: p = exp(s - m_new); sum(p)
            p0, p1, p2 = (_rot(i + 6) + k for k in range(3))
            a.vbcast(p2, ac)                   # clamp const
            a.vbcast(p0, az)                   # partial sum = 0
            with a.repeat(bchunks):
                a.vle(p1, aS + j0 * 4, stride=32)
                a.vsub(p1, p1, m0)
                common.emit_exp(a, p1, p2)
                a.vse(p1, aS + j0 * 4, stride=32)
                a.vredsum(p0, p0, p1)          # p0[0] accumulates sum
                a.scalar(1)
            a.vses(p0, asum)

            # ---- phase 4: corr = exp(m_old - m_new); l = l*corr + sum(p)
            c0, c1, c2 = (_rot(i + 9) + k for k in range(3))
            a.vle(c0, amold)
            a.vsub(c0, c0, m0)
            a.vbcast(c2, ac)
            common.emit_exp(a, c0, c2)         # corr (all lanes)
            a.vle(c1, al)
            a.vmul(c1, c1, c0)
            a.vbcast(c2, asum)
            a.vadd(c1, c1, c2)
            a.vse(c1, al)

            # ---- phase 5: acc = acc*corr + P . V  (vectorised over d)
            with a.repeat(dc):
                a.vle(c1, aacc, stride=32)
                a.vmul(c1, c1, c0)
                a.vse(c1, aacc, stride=32)
            v0, v1, v2 = (_rot(i + 12) + k for k in range(3))
            with a.repeat(jn):
                a.vbcast(v0, aS + j0 * 4, stride=4)       # p_j
                with a.repeat(dc):
                    a.vle(v1, av + j0 * d * 4,
                          strides=(32, d * 4) + sfx(0, "v"))
                    a.vle(v2, aacc, stride=32)
                    a.vmacc(v2, v0, v1)
                    a.vse(v2, aacc, stride=32)
                a.scalar(2)

        # ---- epilogue: O[i] = acc / l
        o0, o1, _ = (_rot(i + 15) + k for k in range(3))
        a.vle(o1, al)
        with a.repeat(dc):
            a.vle(o0, aacc, stride=32)
            a.vdiv(o0, o0, o1)
            a.vse(o0, ao + i * d * 4, strides=(32,) + sfx(grp_adv, "o"))
        a.scalar(3)

    if group > 1:
        with a.repeat(seq // group):
            for i0 in range(group):
                emit_row(i0)
    else:
        for i in range(seq):
            emit_row(i)


def reference_attention(Q, K, V, bc: int) -> np.ndarray:
    """f64 mirror of :func:`emit_attention`: same blocking, same exp
    approximation, same association order."""
    seq, d = Q.shape
    scale = 1.0 / np.sqrt(d)
    n_blocks = (seq + bc - 1) // bc
    Qd, Kd, Vd = (x.astype(np.float64) for x in (Q, K, V))
    O = np.zeros((seq, d))
    for i in range(seq):
        m, l = NEG, 0.0
        acc = np.zeros(d)
        for b in range(n_blocks):
            j0 = b * bc
            jn = min(bc, seq - j0)
            s = scale * (Kd[j0:j0 + jn] @ Qd[i])
            m_new = max(m, s.max())
            p = common.np_exp_approx(s - m_new)
            corr = float(common.np_exp_approx(np.array(m - m_new)))
            l = l * corr + p.sum()
            acc = acc * corr + p @ Vd[j0:j0 + jn]
            m = m_new
        O[i] = acc / l
    return O


@common.register_benchmark(
    "flashattention2", domain="Transformer", paper_params=PAPER,
    reduced_params=REDUCED,
    table2="Seq. Length:200 Hidden Dim.:64 Block row:1 Block col:128")
def build(seq=200, d=64, bc=128, seed=0) -> common.Built:
    assert seq % VL == 0 and d % VL == 0 and bc % VL == 0
    g = common.rng(seed)
    Q = (g.standard_normal((seq, d)) * 0.3).astype(np.float32)
    K = (g.standard_normal((seq, d)) * 0.3).astype(np.float32)
    V = g.standard_normal((seq, d)).astype(np.float32)

    mm = MemoryMap()
    bufs = dict(
        aq=mm.alloc("Q", Q),
        akt=mm.alloc("KT", np.ascontiguousarray(K.T)),   # (d, seq)
        av=mm.alloc("V", V),
        ao=mm.alloc("O", seq * d),
    )
    bufs.update(scratch_buffers(mm, seq, d))

    a = Assembler("flashattention2")
    emit_attention(a, bufs, seq, d, bc)
    prog = a.finalize(mm)

    O = reference_attention(Q, K, V, bc)
    return common.Built(prog, {"O": O.astype(np.float32)},
                        rtol=5e-3, atol=1e-4)


def reference_softmax(seq=200, d=64, seed=0, **_) -> np.ndarray:
    """True-softmax attention for the loose sanity check in tests."""
    g = common.rng(seed)
    Q = (g.standard_normal((seq, d)) * 0.3).astype(np.float32)
    K = (g.standard_normal((seq, d)) * 0.3).astype(np.float32)
    V = g.standard_normal((seq, d)).astype(np.float32)
    s = (Q @ K.T) / np.sqrt(d)
    p = np.exp(s - s.max(axis=1, keepdims=True))
    p /= p.sum(axis=1, keepdims=True)
    return p @ V


def scalar_cost(seq=200, d=64, **_) -> ScalarCost:
    # scores+PV: 2*seq^2*d MACs + lw; scalar softmax pays a libm-style
    # exp (~25 flop-equivalents per element).
    macs = 2 * seq * seq * d
    sm = 25 * seq * seq
    return ScalarCost(flop_ops=macs + sm, loads=macs + 2 * seq * seq,
                      stores=seq * d + 2 * seq * seq,
                      unique_lines=(3 * seq * d) // 8 * (seq // 16),
                      loop_iters=macs)
