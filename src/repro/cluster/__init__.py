"""Clustered vector units: N dispersion cores behind shared memory.

``repro.cluster`` lifts the fused single-core engine to a lockstep
N-core cluster (private cVRF + L1 per core, shared L2 + banked memory
channels with deterministic round-robin arbitration) — still one
``lax.scan`` per sweep, so a whole cores x capacity x policy x latency
grid is one XLA dispatch.  See ``docs/cluster.md`` for the arbiter spec
and the iso-SRAM-budget sweep methodology.
"""

from repro.cluster.contention import (ClusterConfig, l2_access, l2_init,
                                      queue_rounds, rank_order)
from repro.cluster.engine import (CLUSTER_COUNTER_NAMES,
                                  CORE_CYCLE_AGGREGATES,
                                  check_cluster_affine,
                                  simulate_cluster_grid)

__all__ = [
    "ClusterConfig", "CLUSTER_COUNTER_NAMES", "CORE_CYCLE_AGGREGATES",
    "check_cluster_affine", "l2_access", "l2_init", "queue_rounds",
    "rank_order", "simulate_cluster_grid",
]
