"""Fused N-core cluster sweep engine.

Lifts the single-core fused engine (``core/simulator.py``) to a cluster
of N homogeneous dispersion cores behind a shared L2 and banked memory
channels (``cluster/contention.py``), still as ONE ``lax.scan``:

  * the per-instruction engine body (``simulator._make_body``) is vmapped
    over a leading core axis — private cVRF, L1 and spill state per core,
    all N cores retiring the same instruction in lockstep (worst-case
    -aligned contention);
  * each core runs the trace in its own **address colour**: core i's
    spill region and data lines are offset by ``i * stride`` (stride =
    the program footprint rounded up to odd, so per-core L1 set mappings
    genuinely differ while core 0 is untouched — the N=1 identity);
  * the cores' per-instruction L1-miss streams
    (``simulator.NUM_MISS_SITES`` sites each) are drained *inside the
    same scan step* through the shared L2 in round-robin core order, and
    the survivors queue on the memory channels
    (:func:`repro.cluster.contention.queue_rounds`), charging each core
    a ``contention_stalls`` increment that is a latency-independent
    multiple of ``mem_latency``.

Counter layout: :data:`CLUSTER_COUNTER_NAMES` = the single-core
``COUNTER_NAMES`` + (``contention_stalls``, ``l2_hits``, ``l2_misses``).
``cycles`` absorbs the contention adjustment
``l2_hits * (l2_hit_cycles - mem_latency) + contention_stalls`` per core,
so per-core cycles stay exactly affine in the traced latencies
(:func:`check_cluster_affine`); the *aggregate* cluster ``cycles`` is the
makespan (max over cores), which is only piecewise affine — the affine
cross-check therefore runs on the per-core grid.

Compile/dispatch accounting increments the same
``simulator._COMPILES`` / ``_DISPATCHES`` counters, so ``repro.api``'s
session accounting sees cluster work with no extra plumbing: one compile
per (shape bucket x L1 geometry x ClusterConfig).
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import contention
from repro.cluster.contention import ClusterConfig
from repro.core import events as ev_mod
from repro.core import costmodel, isa, policies, simulator
from repro.core.simulator import (DEFAULT_MACHINE, MachineSweep,
                                  PreparedTrace, SweepConfig)

CLUSTER_COUNTER_NAMES = simulator.COUNTER_NAMES + (
    "contention_stalls", "l2_hits", "l2_misses",
)

# Aggregate-only outputs derived from the per-core cycles column.
CORE_CYCLE_AGGREGATES = ("core_cycles_min", "core_cycles_max",
                         "core_cycles_sum")


def _stride(prep: PreparedTrace) -> int:
    """Per-core address-colour stride: one core's whole footprint (spill
    region + data lines), rounded up to odd so consecutive colours land on
    different L1/L2 sets (set counts are powers of two)."""
    mem_max = int(np.max(prep.ev.mem_line, initial=-1))
    footprint = max(prep.spill_line0 + isa.NUM_ARCH_VREGS, mem_max + 1)
    return footprint | 1


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4),
                   donate_argnums=(5, 6, 7))
def _run_cluster_grid(cluster, l1_sets, l1_ways, slots_used, track_ab,
                      arrays, spill0s, strides, cfg, mach):
    """(P, T) trace grid x (C,) configs x (M,) machines x N lockstep cores
    -> (P, C, M, N, 15) per-core cluster counters (x3 for the A/B fold
    certificate).  Statics mirror ``simulator._run_grid`` plus the whole
    (hashable) :class:`ClusterConfig`; the jit cache therefore compiles
    once per (bucket, L1 geometry, cluster) plan group."""
    simulator._COMPILES += 1
    N = cluster.n_cores
    n_ctr = len(CLUSTER_COUNTER_NAMES)
    core_ids = jnp.arange(N, dtype=jnp.int32)

    def one_program(arr, sp0, stride):
        def one_cfg(c):
            def one_machine(m):
                body = simulator._make_body(l1_sets, slots_used, c, m)
                mem_lat = m[2]
                spill_bases = sp0.astype(jnp.int32) + core_ids * stride
                mem_bases = core_ids * stride
                caches = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (N,) + x.shape),
                    policies.CacheState.init(isa.NUM_ARCH_VREGS))
                l1s = jnp.broadcast_to(
                    simulator._l1_init(l1_sets, l1_ways),
                    (N, l1_sets, l1_ways, 2))
                z = jnp.zeros((N, n_ctr), jnp.int32)
                # The L2 access clock starts at 1: stored ages stay
                # strictly positive, so a just-filled line never ties with
                # a free way (age 0) in the LRU argmin.
                carry = (caches, l1s, jnp.zeros(N, jnp.int32),
                         contention.l2_init(cluster.l2_sets,
                                            cluster.l2_ways),
                         jnp.int32(1), jnp.int32(0), jnp.int32(0),
                         z, z, z)

                def step(carry, xs):
                    (caches, l1s, seqs, l2, clk, t, now0,
                     ctr, ctrA, ctrB) = carry
                    wt, wa, wb = xs[-3:]
                    (caches, l1s, seqs), incs, miss_lines = jax.vmap(
                        lambda st, sb, mb: body(st, xs, sb, mb, now0)
                    )((caches, l1s, seqs), spill_bases, mem_bases)
                    # Shared L2 + channel arbiter, in RR core order.
                    order = contention.rank_order(N, t)
                    lines_rr = miss_lines[order].reshape(
                        N * simulator.NUM_MISS_SITES)
                    if cluster.l2_sets:
                        def l2_step(c2, line):
                            l2_, clk_ = c2
                            l2_, hit = contention.l2_access(
                                l2_, line, clk_, cluster.l2_sets)
                            return (l2_, clk_ + (line >= 0)), hit
                        (l2, clk), hits_rr = jax.lax.scan(
                            l2_step, (l2, clk), lines_rr)
                    else:
                        hits_rr = jnp.zeros(lines_rr.shape, bool)
                    site_hit = hits_rr.reshape(
                        N, simulator.NUM_MISS_SITES)
                    site_req = (lines_rr >= 0).reshape(
                        N, simulator.NUM_MISS_SITES) & ~site_hit
                    l2h_rr = site_hit.sum(1).astype(jnp.int32)
                    reqs_rr = site_req.sum(1).astype(jnp.int32)
                    q_rr = contention.queue_rounds(reqs_rr,
                                                   cluster.mem_channels)
                    zc = jnp.zeros(N, jnp.int32)      # rank -> core scatter
                    l2h = zc.at[order].set(l2h_rr)
                    reqs = zc.at[order].set(reqs_rr)
                    stall = zc.at[order].set(q_rr) * mem_lat
                    cyc = (incs[:, 0] + stall
                           + l2h * (cluster.l2_hit_cycles - mem_lat))
                    inc_full = jnp.concatenate(
                        [cyc[:, None], incs[:, 1:], stall[:, None],
                         l2h[:, None], reqs[:, None]], axis=1)
                    ctr = ctr + inc_full * wt
                    if track_ab:
                        ctrA = ctrA + inc_full * wa
                        ctrB = ctrB + inc_full * wb
                    return (caches, l1s, seqs, l2, clk, t + 1,
                            now0 + ev_mod.NUM_SLOTS, ctr, ctrA, ctrB), None

                out = jax.lax.scan(step, carry, arr)[0]
                return out[-3], out[-2], out[-1]
            return jax.vmap(one_machine)(mach)
        return jax.vmap(one_cfg)(cfg)

    return jax.vmap(one_program)(arrays, spill0s, strides)


def _dispatch_cluster_grid(cluster, machine, slots_used, track_ab, arrays,
                           spill0s, strides, cfg, mach):
    simulator._DISPATCHES += 1
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _run_cluster_grid(
            cluster, machine.l1_sets, machine.l1_ways, slots_used, track_ab,
            tuple(jnp.asarray(a) for a in arrays), jnp.asarray(spill0s),
            jnp.asarray(strides), cfg, mach)


def simulate_cluster_grid(preps: list, sweep: SweepConfig,
                          machine=DEFAULT_MACHINE,
                          cluster: ClusterConfig = ClusterConfig(),
                          batch_programs: bool = False,
                          return_per_core: bool = False) -> dict:
    """Cluster analogue of :func:`repro.core.simulator.simulate_grid`.

    Returns (P, C) — or (P, C, M) under a :class:`MachineSweep` — arrays
    for every :data:`CLUSTER_COUNTER_NAMES` counter, aggregated over the N
    cores: ``cycles`` is the cluster **makespan** (max over cores, the
    time until the last core retires), every other counter is the sum.
    ``core_cycles_min/max/sum`` expose the per-core cycles spread (the
    fairness margin), and ``fold_exact`` / ``hit_rate`` / ``event_scale``
    carry over with their single-core semantics (a fold is certified only
    if A == B on *every* core's full counter vector).

    ``return_per_core=True`` additionally returns ``out["per_core"]``, a
    dict of (..., N) per-core counter grids — the input shape for
    :func:`check_cluster_affine` (makespan is only piecewise affine in the
    latencies; each core's counters are exactly affine).
    """
    preps = [simulator.prepare(p) if not isinstance(p, PreparedTrace) else p
             for p in preps]
    squeeze_m = not isinstance(machine, MachineSweep)
    machines = MachineSweep.from_params([machine]) if squeeze_m else machine
    cfg = (jnp.asarray(sweep.capacity), jnp.asarray(sweep.policy),
           jnp.asarray(sweep.alloc_no_fetch))
    mach = (jnp.asarray(machines.l1_hit_cycles),
            jnp.asarray(machines.uop_hit_cycles),
            jnp.asarray(machines.mem_latency))
    strides = np.asarray([_stride(p) for p in preps], np.int32)
    if batch_programs:
        arrays, spill0s, slots_used = simulator._stack(preps)
        track_ab = any(p.num_folds for p in preps)
        ctr, ctrA, ctrB = _dispatch_cluster_grid(
            cluster, machines, slots_used, track_ab, arrays, spill0s,
            strides, cfg, mach)
        ctr, ctrA, ctrB = (np.asarray(x) for x in (ctr, ctrA, ctrB))
    else:
        outs = []
        for prep, stride in zip(preps, strides):
            arrays, spill0s, slots_used = simulator._stack([prep])
            outs.append(_dispatch_cluster_grid(
                cluster, machines, slots_used, prep.num_folds > 0, arrays,
                spill0s, stride[None], cfg, mach))
        ctr = np.concatenate([np.asarray(o[0]) for o in outs])
        ctrA = np.concatenate([np.asarray(o[1]) for o in outs])
        ctrB = np.concatenate([np.asarray(o[2]) for o in outs])
    if squeeze_m:                                   # (P, C, M, N, 15)
        ctr, ctrA, ctrB = ctr[:, :, 0], ctrA[:, :, 0], ctrB[:, :, 0]
    per_core = {k: ctr[..., i] for i, k in enumerate(CLUSTER_COUNTER_NAMES)}
    cyc = per_core["cycles"]
    out = {"cycles": cyc.max(axis=-1)}
    for name in CLUSTER_COUNTER_NAMES[1:]:
        out[name] = per_core[name].sum(axis=-1)
    out["core_cycles_min"] = cyc.min(axis=-1)
    out["core_cycles_max"] = cyc.max(axis=-1)
    out["core_cycles_sum"] = cyc.sum(axis=-1)
    grid_shape = out["cycles"].shape              # (P, C) or (P, C, M)
    per_prog = (-1,) + (1,) * (len(grid_shape) - 1)
    if any(p.num_folds for p in preps):
        steady = (ctrA == ctrB).all(axis=(-1, -2))
        steady &= np.asarray(
            [p.certifiable for p in preps]).reshape(per_prog)
        unfolded = np.asarray([p.num_folds == 0 for p in preps])
        steady[unfolded] = True
        out["fold_exact"] = steady
    total = out["vrf_hits"] + out["vrf_misses"]
    with np.errstate(divide="ignore", invalid="ignore"):
        out["hit_rate"] = np.where(total > 0, out["vrf_hits"] / total, 1.0)
    out["event_scale"] = np.broadcast_to(
        np.asarray([p.event_scale for p in preps]).reshape(per_prog),
        grid_shape).copy()
    if return_per_core:
        out["per_core"] = per_core
    return out


def check_cluster_affine(per_core: dict, machines: MachineSweep) -> dict:
    """Machine-latency affinity cross-check, per core.

    ``per_core`` is ``simulate_cluster_grid(..., return_per_core=True)
    ["per_core"]`` with shape (..., M, N).  Each core's ``cycles`` /
    ``stall_cycles`` / ``contention_stalls`` must be exactly affine in the
    traced latencies and every other counter machine-invariant — the L2
    and arbiter only ever consult hit/miss decisions.  The ``mem_latency``
    slope floor is ``l1_misses - l2_hits``: every L2 hit converts one
    memory transfer into a (static) ``l2_hit_cycles`` term, while channel
    queueing only adds whole ``mem_latency`` rounds on top.
    """
    cnt = {k: np.swapaxes(np.asarray(v), -1, -2)      # (..., N, M)
           for k, v in per_core.items()}
    floor = cnt["l1_misses"][..., 0] - cnt["l2_hits"][..., 0]
    return costmodel.check_machine_affine(
        cnt, machines,
        timing=("cycles", "stall_cycles", "contention_stalls"),
        mem_slope_floor=floor)
