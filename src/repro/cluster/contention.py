"""Shared-memory contention model for clustered vector cores.

A cluster (Spatz-style, arXiv:2309.10137) puts N homogeneous
dispersion cores — each with its private cVRF + L1 — behind one shared
L2 and a banked main-memory interface.  This module holds the *static*
cluster description and the two pure pieces the engine composes per
scan step:

  * a shared **L2 lookup** (sets x ways, LRU, read-allocate on demand
    misses; dirty L1 writebacks are absorbed by a write buffer and
    bypass both the L2 and the arbiter), and
  * a deterministic **round-robin banked-channel arbiter**: the L1-miss
    streams that also miss the L2 contend for ``mem_channels`` memory
    banks.  Requests issued in the same lockstep instruction slot are
    served in round-robin core order (the RR pointer advances one core
    per instruction), each bank serving one request per ``mem_latency``
    window; a request finding ``b`` earlier-ranked requests queued waits
    ``(b // mem_channels) * mem_latency`` extra cycles.

Only *cross-core* queueing is charged here: the single-core engine
already serializes a core's own misses at ``mem_latency`` each, so the
arbiter's exclusive-cumsum over earlier-ranked cores never double-counts
— and an N=1 cluster gets identically zero contention, which is the
bit-identity pin in ``tests/test_golden_counters.py``.

Every quantity the arbiter derives (L2 hits, queue rounds) depends only
on hit/miss *decisions*, never on the latency values, so cluster cycle
counts stay affine in the traced machine latencies and
``costmodel.check_machine_affine`` extends to the cluster
(:func:`repro.cluster.engine.check_cluster_affine`).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import simulator


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Static description of one cluster of dispersion cores.

    Every field is static (hashable) — like ``l1_sets``/``l1_ways``, the
    core count and L2 geometry size engine state arrays, so each distinct
    ``ClusterConfig`` is its own compiled executable (its own plan group
    in ``repro.api``).  The per-core cVRF capacity/policy stays on the
    existing :class:`repro.core.simulator.SweepConfig` axis and the L1
    geometry + latencies on :class:`~repro.core.simulator.MachineSweep`;
    this class only adds what is *shared*: the L2 and the memory
    channels.  ``l2_hit_cycles`` is static (not a traced latency axis) so
    cluster cycles remain affine in the three traced latencies.
    """

    n_cores: int = 1
    l2_sets: int = 0          # 0 => no shared L2 (pass-through to memory)
    l2_ways: int = 4
    mem_channels: int = 1     # memory banks serving one request / latency
    l2_hit_cycles: int = 2    # static: replaces mem_latency on an L2 hit

    def __post_init__(self):
        if self.n_cores < 1:
            raise ValueError(f"n_cores must be >= 1, got {self.n_cores}")
        if self.mem_channels < 1:
            raise ValueError(
                f"mem_channels must be >= 1, got {self.mem_channels}")
        if self.l2_sets and self.l2_sets & (self.l2_sets - 1):
            raise ValueError(
                f"l2_sets must be 0 or a power of two, got {self.l2_sets}")

    @staticmethod
    def passthrough(n_cores: int = 1) -> "ClusterConfig":
        """The identity cluster: no shared L2 and enough channels that the
        arbiter can never queue (a step issues at most NUM_MISS_SITES
        requests per core, so ``n_cores * NUM_MISS_SITES`` banks make every
        exclusive-cumsum queue depth round down to zero).  An N=1
        passthrough cluster reproduces the single-core engine's counters
        bit-exactly."""
        return ClusterConfig(
            n_cores=n_cores, l2_sets=0, l2_ways=1,
            mem_channels=n_cores * simulator.NUM_MISS_SITES)

    @property
    def l2_bytes(self) -> int:
        """Shared-L2 data capacity (32 B lines, matching the L1 model)."""
        return self.l2_sets * self.l2_ways * 32


def l2_init(l2_sets: int, l2_ways: int):
    """Shared-L2 state: (sets, ways, 2) int32 with [:, :, 0] the line tag
    (-1 free) and [:, :, 1] the LRU age — a carried access clock rather
    than the L1's packed slot-grid timestamp, since cluster traces touch
    the L2 far fewer times than there are slot-grid ticks (the clock stays
    far from int32 overflow)."""
    l2 = jnp.zeros((max(l2_sets, 1), l2_ways, 2), jnp.int32)
    return l2.at[:, :, 0].set(-1)


def l2_access(l2, line, clock, l2_sets: int):
    """One shared-L2 probe for an L1-missed ``line`` (-1 => no request).

    Returns ``(l2', hit)``.  LRU within the set, allocate on miss; the
    state update is a no-op for inactive (-1) requests.  Hit/miss
    decisions depend only on the request stream, never on latencies."""
    active = line >= 0
    set_idx = jnp.where(active, line, 0) % l2_sets
    row = l2[set_idx]                              # (ways, 2)
    eq = row[:, 0] == line
    hit = eq.any() & active
    way = jnp.where(hit, jnp.argmax(eq), jnp.argmin(row[:, 1]))
    new = jnp.stack([line, clock])
    l2_new = l2.at[set_idx, way].set(jnp.where(active, new, row[way]))
    return l2_new, hit


def rank_order(n_cores: int, t):
    """Round-robin service order for instruction ``t``: rank r is served
    r-th this step, and ``rank_order(...)[r]`` is the core holding that
    rank.  The RR pointer advances one core per instruction so every core
    periodically goes first — the fairness property pinned in
    ``tests/test_cluster.py``."""
    return (t % n_cores + jnp.arange(n_cores, dtype=jnp.int32)) % n_cores


def queue_rounds(reqs_rr, mem_channels: int):
    """Banked-channel queue depth per rank: with requests served in rank
    order, one per channel per ``mem_latency`` window, rank r's requests
    wait behind the exclusive cumsum of earlier ranks' requests and stall
    ``(before // mem_channels)`` full memory latencies.  Rank 0 (and all of
    an N=1 cluster) always gets 0."""
    before = jnp.cumsum(reqs_rr) - reqs_rr
    return before // mem_channels
