"""Trace-from-model bridge: registry models -> certified foldable sweeps.

Pipeline (one module per stage, see docs/bridge.md for the contract):

- :mod:`repro.bridge.shapes` — walk a :mod:`repro.configs.registry` model,
  extract every layer's concrete shapes via the :mod:`repro.models` init
  functions (``jax.eval_shape``, no parameter memory), emit
  :class:`LayerOp` records (gemm / attn / scan) with network-level counts.
- :mod:`repro.bridge.lower` — lower each op kind to a fixed-shape tile
  program built from ``Assembler.repeat`` deep nests with way-span-padded
  planes, so the outer loops certify exact under :mod:`repro.core.folding`.
- :mod:`repro.bridge.network` — deduplicate by shape signature, register
  one benchmark per unique signature (``net:*`` names, domain
  ``"network"``), and report per-model totals from per-kernel sweeps.

Front door: ``Sweep(network=("granite-8b", ...))`` in :mod:`repro.api`.
"""

from repro.bridge.shapes import TOKEN_BLOCK, LayerOp, model_ops
from repro.bridge.lower import (ATTN_TILE, K_CAP, MT, N_CAP, SCAN_STEPS,
                                SCAN_WIDTH_CAP, TILES, build_attn,
                                build_gemm, build_scan, tile_for)
from repro.bridge.network import (LoweredNetwork, NetworkUnit,
                                  lower_network, network_report)

__all__ = [
    "TOKEN_BLOCK", "LayerOp", "model_ops",
    "ATTN_TILE", "K_CAP", "MT", "N_CAP", "SCAN_STEPS", "SCAN_WIDTH_CAP",
    "TILES", "build_attn", "build_gemm", "build_scan", "tile_for",
    "LoweredNetwork", "NetworkUnit", "lower_network", "network_report",
]
