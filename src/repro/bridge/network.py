"""Network-level driver: lower a registry model into registered kernels.

``lower_network(model)`` walks the model's LayerOps (:mod:`.shapes`),
deduplicates them by shape signature, registers one benchmark per unique
signature through the ordinary ``@register_benchmark`` registry (domain
``"network"``, idempotent via ``exist_ok``), and returns a
:class:`LoweredNetwork` mapping every layer instance onto its kernel with
a count and macro factor.  Because the registered kernels are plain
benchmarks, a whole model becomes one ``Sweep(kernels=net.kernels, ...)``
— or, via the ``network`` axis on :class:`repro.api.Sweep`, just
``Sweep(network=("granite-8b", ...))``.

``network_report`` folds per-kernel sweep results back into per-model
totals: each unit's counters scale by ``count * macro_factor`` (tile
programs cover a fixed sub-problem; the macro factor is real-work /
tile-work, see :mod:`.lower`).
"""

from __future__ import annotations

import dataclasses

from repro.bridge import lower, shapes
from repro.rvv import common as rvv_common


@dataclasses.dataclass(frozen=True)
class NetworkUnit:
    """One deduplicated layer group of a lowered network."""

    kernel: str           # registered benchmark name (net:<kind>:<shape>)
    kind: str             # gemm | attn | scan
    labels: tuple         # layer labels merged into this unit
    shape: tuple          # real layer shape (signature dims)
    count: int            # instances across the network
    macro_factor: float   # real work / tile work, per instance
    params: dict          # tile build kwargs

    @property
    def scale(self) -> float:
        """Counter multiplier taking one tile run to network-level work."""
        return self.count * self.macro_factor


@dataclasses.dataclass(frozen=True)
class LoweredNetwork:
    model: str
    units: tuple

    @property
    def kernels(self) -> tuple:
        """Sorted unique kernel names (the Sweep kernel axis)."""
        return tuple(sorted({u.kernel for u in self.units}))

    @property
    def num_instances(self) -> int:
        return sum(u.count for u in self.units)

    def summary(self) -> dict:
        """JSON-friendly description (lands in ``Session.run`` meta)."""
        return dict(model=self.model, kernels=list(self.kernels),
                    units=len(self.units), instances=self.num_instances)


def _register(name: str, kind: str, kwargs: dict, op) -> None:
    rvv_common.register_benchmark(
        name, domain="network", paper_params=dict(kwargs),
        reduced_params=dict(kwargs),
        table2=f"bridge-lowered {kind} {'x'.join(map(str, op.shape))}",
        scalar_cost=lower.cost_for(kind), exist_ok=True,
    )(lower.builder_for(kind))


def lower_network(model: str) -> LoweredNetwork:
    """Lower registry model ``model``; idempotent (re-lowering a model, or
    lowering two models sharing a layer shape, reuses registered kernels).
    """
    groups: dict[tuple, list] = {}
    for op in shapes.model_ops(model):
        groups.setdefault(op.signature, []).append(op)
    units = []
    for sig, ops in sorted(groups.items(), key=lambda kv: repr(kv[0])):
        name, kwargs, macro = lower.tile_for(ops[0])
        _register(name, ops[0].kind, kwargs, ops[0])
        units.append(NetworkUnit(
            kernel=name, kind=ops[0].kind,
            labels=tuple(o.label for o in ops), shape=tuple(sig[1:]),
            count=sum(o.count for o in ops), macro_factor=macro,
            params=dict(kwargs)))
    return LoweredNetwork(model=model, units=tuple(units))


def network_report(result, lowered, metrics=("scaled_cycles",),
                   capacity_bytes_per_reg: int = 32) -> list[dict]:
    """Per-model totals from a per-kernel sweep result.

    ``result``: a ``SweepResult`` whose first axis is ``kernel`` and whose
    data contains every name in ``metrics`` (``derive`` them first).
    ``lowered``: a LoweredNetwork or list thereof; every unit's kernel
    must be on the result's kernel axis.  One row per (model, non-kernel
    grid point): the point's axis labels, the model's cVRF footprint
    (capacity x 32 B vector registers), and ``<metric>_total`` — the
    count x macro-factor weighted sum of the metric over the model's
    units (tile counters scaled back to network-level work).
    """
    import numpy as np

    if isinstance(lowered, LoweredNetwork):
        lowered = [lowered]
    kaxis = result.axis("kernel")
    if result.axes[0].name != "kernel":
        raise ValueError("network_report expects kernel as the first axis")
    ki_for = {n: i for i, n in enumerate(kaxis.values)}
    rows = []
    other = result.axes[1:]
    for idx in np.ndindex(*(len(a) for a in other)):
        labels = result._labels((0,) + idx)
        labels.pop("kernel", None)
        for net in lowered:
            row = dict(model=net.model, **labels)
            row["kernels"] = len(net.kernels)
            row["instances"] = net.num_instances
            if "capacity" in row:
                row["footprint_bytes"] = (int(row["capacity"])
                                          * capacity_bytes_per_reg)
            for m in metrics:
                vals = result.data[m]
                total = 0.0
                for u in net.units:
                    total += float(vals[(ki_for[u.kernel],) + idx]) * u.scale
                row[f"{m}_total"] = total
            rows.append(row)
    return rows
