"""Layer-shape extraction: configs registry -> concrete LayerOp lists.

The bridge walks a model from :mod:`repro.configs.registry` and asks the
existing :mod:`repro.models` init functions — via ``jax.eval_shape``, so no
parameter memory is ever allocated — for every weight's concrete shape.
Each 2-D weight ``(K, N)`` becomes a GEMM op; each 3-D per-expert weight
``(E, K, N)`` becomes a GEMM op counted once per *active* expert
(``moe_top_k``); attention, Mamba-scan and RG-LRU blocks additionally emit
one dynamic op (``attn`` / ``scan``) for the part of the layer that is not
a weight GEMM.  Non-GEMM parameters (1-D vectors, the SSM ``a_log`` decay
table, the depthwise ``conv_w``) are skipped explicitly.

The workload unit is a **token block** of :data:`TOKEN_BLOCK` tokens: every
GEMM processes TOKEN_BLOCK rows, attention covers a TOKEN_BLOCK-long
context, and recurrences run TOKEN_BLOCK steps.  Lowered tiles cover a
fixed sub-problem; the ratio real-work / tile-work is the op's macro
factor (see :mod:`repro.bridge.lower`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models.attention import init_attention, init_mla
from repro.models.mlp import init_mlp, init_moe
from repro.models.rglru import init_rglru
from repro.models.ssm import init_mamba

#: Tokens processed per workload unit (GEMM M rows, attention context
#: length, recurrence steps).
TOKEN_BLOCK = 128

#: Parameters that are 2-D but not GEMM weights: the SSM decay table and
#: the depthwise conv kernel (its work is a scan-shaped stencil, covered by
#: the layer's scan op), plus anything 1-D.
_SKIP_NAMES = frozenset({"a_log", "conv_w"})

_WHISPER_MELS = 80        # audio frontend: log-mel bins, conv kernel 3
_VISION_PATCH = 3 * 14 * 14   # vision frontend: RGB 14x14 patch embedding


@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One lowered unit of network work.

    ``kind``: ``gemm`` (shape ``(K, N)``: x(M,K) @ W(K,N)), ``attn`` (shape
    ``(heads, head_dim)``) or ``scan`` (shape ``(width,)``: elementwise
    recurrence over ``width`` channels).  ``count`` is how many instances
    the whole network runs per token block (layers x multiplicity).
    """

    kind: str
    label: str
    shape: tuple
    count: int

    @property
    def signature(self) -> tuple:
        """Dedup key: kind + concrete dims.  Label-free on purpose — two
        layers with the same shape lower to the same program."""
        return (self.kind,) + tuple(self.shape)

    @property
    def work(self) -> int:
        """Scalar work per instance (MACs for gemm/attn, element updates
        for scan) at the TOKEN_BLOCK workload unit."""
        if self.kind == "gemm":
            k, n = self.shape
            return TOKEN_BLOCK * k * n
        if self.kind == "attn":
            heads, hd = self.shape
            return 2 * TOKEN_BLOCK * TOKEN_BLOCK * hd * heads
        (width,) = self.shape
        return TOKEN_BLOCK * width


def _weight_shapes(init_fn, *args, **kwargs) -> list[tuple[str, tuple]]:
    """(name, shape) per weight of an init function, via ``jax.eval_shape``
    (shape inference only — no arrays are materialised)."""
    tree = jax.eval_shape(
        lambda key: init_fn(key, *args, jnp.float32, **kwargs),
        jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        out.append((name, tuple(leaf.shape)))
    return out


def _gemm_ops(prefix: str, weights, count: int, top_k: int) -> list[LayerOp]:
    ops = []
    for name, shape in weights:
        if name in _SKIP_NAMES or len(shape) < 2:
            continue
        if len(shape) == 2:
            k, n = shape
            mult = 1
        elif len(shape) == 3:           # per-expert (E, K, N): top_k active
            _, k, n = shape
            mult = max(1, top_k)
        else:
            continue
        ops.append(LayerOp("gemm", f"{prefix}/{name}", (int(k), int(n)),
                           count * mult))
    return ops


def _head_geometry(cfg) -> tuple[int, int]:
    """(heads, qk head dim) — for MLA the decompressed per-head QK width."""
    hd = cfg.head_dim or cfg.d_model // cfg.num_heads
    if cfg.mla:
        hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return cfg.num_heads, hd


@functools.lru_cache(maxsize=None)
def model_ops(model: str) -> tuple[LayerOp, ...]:
    """All LayerOps of registry model ``model``, with network-level counts.

    Block composition mirrors :meth:`ArchConfig.param_count`: dense /
    MoE MLPs, (ML)A attention, Mamba blocks, hybrid attention/RG-LRU
    interleave (layer i is attention iff ``i % 3 == 2``), Whisper
    encoder-decoder (decoder layers carry self- plus cross-attention), the
    modality frontend as an im2col GEMM, and the LM head.
    """
    cfg = registry.get(model)
    d, l = cfg.d_model, cfg.num_layers
    ops: list[LayerOp] = []

    # ---- attention / recurrence block mix ------------------------------
    if cfg.ssm:
        ops += _gemm_ops("ssm", _weight_shapes(init_mamba, cfg), l, 0)
        din = cfg.ssm_expand * d
        ops.append(LayerOp("scan", "ssm_scan", (din * cfg.ssm_state,), l))
        n_mlp = 0                        # Mamba blocks subsume the MLP
    elif cfg.hybrid:
        n_att = sum(1 for i in range(l) if i % 3 == 2)
        n_rec = l - n_att
        ops += _gemm_ops("attn", _weight_shapes(init_attention, cfg),
                         n_att, 0)
        ops.append(LayerOp("attn", "attention", _head_geometry(cfg), n_att))
        ops += _gemm_ops("rglru", _weight_shapes(init_rglru, cfg), n_rec, 0)
        ops.append(LayerOp("scan", "rglru_scan", (cfg.lru_width or d,),
                           n_rec))
        n_mlp = l
    else:
        init_a = init_mla if cfg.mla else init_attention
        n_att = l + (cfg.num_encoder_layers + l if cfg.encoder_decoder
                     else 0)            # decoder self + cross, encoder self
        ops += _gemm_ops("attn", _weight_shapes(init_a, cfg), n_att, 0)
        ops.append(LayerOp("attn", "attention", _head_geometry(cfg), n_att))
        n_mlp = l + (cfg.num_encoder_layers if cfg.encoder_decoder else 0)

    # ---- MLP / MoE blocks ---------------------------------------------
    if n_mlp:
        if cfg.moe:
            n_dense = cfg.first_dense_layers
            n_moe = n_mlp - n_dense
            if n_dense:
                ops += _gemm_ops(
                    "mlp", _weight_shapes(init_mlp, d, cfg.d_ff,
                                          kind=cfg.mlp_kind), n_dense, 0)
            ops += _gemm_ops("moe", _weight_shapes(init_moe, cfg), n_moe,
                             cfg.moe_top_k)
        else:
            ops += _gemm_ops(
                "mlp", _weight_shapes(init_mlp, d, cfg.d_ff,
                                      kind=cfg.mlp_kind), n_mlp, 0)

    # ---- frontend + LM head -------------------------------------------
    if cfg.frontend == "audio":          # two k=3 conv1d layers, im2col
        ops.append(LayerOp("gemm", "frontend/conv1", (_WHISPER_MELS * 3, d),
                           1))
        ops.append(LayerOp("gemm", "frontend/conv2", (d * 3, d), 1))
    elif cfg.frontend == "vision":       # patch embedding, im2col
        ops.append(LayerOp("gemm", "frontend/patch", (_VISION_PATCH, d), 1))
    ops.append(LayerOp("gemm", "lm_head", (d, cfg.vocab_size), 1))
    return tuple(ops)
