"""Lowering: LayerOps -> certified-foldable ``Assembler.repeat`` programs.

Every op kind lowers to a fixed-shape *tile* program whose outer loops
certify exact under :mod:`repro.core.folding`:

- ``gemm``: the :mod:`repro.rvv.gemm` broadcast-MAC nest wrapped in a tile
  loop.  Per-tile A and C planes are padded to whole L1 way-spans (8 KB)
  so the tile axis is set-congruent and folds exact, exactly like the mha
  head loop.
- ``attn``: delegates to :func:`repro.rvv.mha.build` at the bridge's
  attention tile — the head loop is already way-span padded there.
- ``scan``: an elementwise recurrence ``h <- a * h + x_t`` (the shared
  shape of the Mamba selective scan and the RG-LRU gate recurrence): ``h``
  and the decay ``a`` live at step-invariant addresses, the per-step input
  plane is way-span padded, so the step loop is set-congruent.

A tile covers a fixed sub-problem of the real layer; the ratio
real-work / tile-work is the layer's *macro factor*, used when aggregating
tile counters back to network totals.  Tile caps (``K_CAP``/``N_CAP``,
``ATTN_TILE``, ``SCAN_WIDTH_CAP``) bound trace length; the real shape
lives on in the kernel name and the macro factor.

``unroll=True`` on the emitters produces the same instruction stream with
explicit Python loops and literal addresses instead of ``repeat`` strides
— the property tests compare the two row-for-row.
"""

from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.simulator import ScalarCost
from repro.core.trace import Assembler, MemoryMap
from repro.rvv import common, mha
from repro.bridge.shapes import TOKEN_BLOCK, LayerOp

# Way-span padding: 8 KB (256 sets x 32 B line) in 4-byte words.  Planes
# padded to this pitch keep outer-loop iterations set-congruent.
_WAY_SPAN_WORDS = 2048

ACC, AR, BR, ZR = 1, 2, 3, 31           # gemm register roles (rvv.gemm)
HR, CR, XR = 4, 5, 6                    # scan register roles

# ---- tile caps (the lowering contract, see docs/bridge.md) ----------------
TILES, MT = 8, 2                        # gemm: 8 way-span tiles x 2 rows
K_CAP, N_CAP = 64, 32                   # gemm reduction / output caps
ATTN_TILE = dict(seq=16, d=32, bc=16, heads=8)
SCAN_STEPS, SCAN_WIDTH_CAP = 12, 512


def _pad(words: int) -> int:
    """Round a plane size up to a whole number of L1 way-spans."""
    return -(-words // _WAY_SPAN_WORDS) * _WAY_SPAN_WORDS


def _round8(x: int) -> int:
    """Clamp to a positive multiple of VL (vector stores need n % 8 == 0)."""
    return max(isa.VL_ELEMS, (x // isa.VL_ELEMS) * isa.VL_ELEMS)


# ---------------------------------------------------------------------------
# gemm tile
# ---------------------------------------------------------------------------


def build_gemm(tiles=TILES, mt=MT, k=K_CAP, n=N_CAP, seed=0,
               unroll=False) -> common.Built:
    """Tiled GEMM: ``tiles`` independent (mt x k) @ (k x n) products against
    a shared B.  A/C planes are way-span padded per tile, so the tile loop
    is set-congruent and folds exact; the inner nest is rvv.gemm's 4-vreg
    broadcast-MAC pattern."""
    assert n % isa.VL_ELEMS == 0 and k >= 1 and mt >= 1
    g = common.rng(seed)
    A = (g.standard_normal((tiles, mt, k)) / np.sqrt(k)).astype(np.float32)
    B = (g.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)
    pa, pc = _pad(mt * k), _pad(mt * n)

    Abuf = np.zeros((tiles, pa), np.float32)
    Abuf[:, : mt * k] = A.reshape(tiles, mt * k)
    mm = MemoryMap()
    aa = mm.alloc("A", Abuf)
    ab = mm.alloc("B", B)
    ac = mm.alloc("C", tiles * pc)
    az = mm.alloc("zero", np.zeros(1, np.float32))

    a = Assembler("net_gemm")
    a.vbcast(ZR, az)
    chunks = n // isa.VL_ELEMS
    if unroll:
        for t in range(tiles):
            for mi in range(mt):
                for c in range(chunks):
                    a.vmv(ACC, ZR)
                    for kk in range(k):
                        a.vbcast(AR, aa + 4 * kk + k * 4 * mi + pa * 4 * t)
                        a.vle(BR, ab + n * 4 * kk + 32 * c)
                        a.vmacc(ACC, AR, BR)
                    a.vse(ACC, ac + 32 * c + n * 4 * mi + pc * 4 * t)
                    a.scalar(3)
                a.scalar(3)
            a.scalar(3)
    else:
        with a.repeat(tiles):            # way-span-padded tile loop
            with a.repeat(mt):
                with a.repeat(chunks):
                    a.vmv(ACC, ZR)
                    with a.repeat(k):
                        a.vbcast(AR, aa, strides=(4, 0, k * 4, pa * 4))
                        a.vle(BR, ab, strides=(n * 4, 32, 0, 0))
                        a.vmacc(ACC, AR, BR)
                    a.vse(ACC, ac, strides=(32, n * 4, pc * 4))
                    a.scalar(3)
                a.scalar(3)
            a.scalar(3)
    prog = a.finalize(mm)

    C = np.zeros((tiles, pc), np.float32)
    for t in range(tiles):
        C[t, : mt * n] = (A[t].astype(np.float64)
                          @ B.astype(np.float64)).astype(np.float32).ravel()
    return common.Built(prog, {"C": C}, rtol=2e-4, atol=1e-5)


def gemm_scalar_cost(tiles=TILES, mt=MT, k=K_CAP, n=N_CAP, **_) -> ScalarCost:
    macs = tiles * mt * k * n
    return ScalarCost(flop_ops=macs, loads=macs + tiles * mt * k,
                      stores=tiles * mt * n,
                      unique_lines=(tiles * mt * (k + n) + k * n) // 8,
                      loop_iters=macs)


# ---------------------------------------------------------------------------
# scan tile
# ---------------------------------------------------------------------------


def build_scan(steps=SCAN_STEPS, width=SCAN_WIDTH_CAP, seed=0,
               unroll=False) -> common.Built:
    """Elementwise recurrence ``h <- a * h + x_t`` over ``width`` channels
    for ``steps`` steps (the data-flow shape shared by the Mamba selective
    scan and the RG-LRU).  ``h`` and ``a`` sit at step-invariant addresses;
    the per-step input plane is way-span padded, so the step loop is
    set-congruent and folds exact."""
    assert width % isa.VL_ELEMS == 0
    g = common.rng(seed)
    h0 = g.standard_normal(width).astype(np.float32)
    coef = (0.5 + 0.4 * g.random(width)).astype(np.float32)
    X = (g.standard_normal((steps, width)) * 0.1).astype(np.float32)
    pw = _pad(width)

    Xbuf = np.zeros((steps, pw), np.float32)
    Xbuf[:, :width] = X
    mm = MemoryMap()
    ah = mm.alloc("h", h0.copy())
    aco = mm.alloc("coef", coef)
    ax = mm.alloc("X", Xbuf)

    a = Assembler("net_scan")
    chunks = width // isa.VL_ELEMS
    if unroll:
        for t in range(steps):
            for c in range(chunks):
                a.vle(HR, ah + 32 * c)
                a.vle(CR, aco + 32 * c)
                a.vmul(HR, HR, CR)
                a.vle(XR, ax + 32 * c + pw * 4 * t)
                a.vadd(HR, HR, XR)
                a.vse(HR, ah + 32 * c)
                a.scalar(2)
            a.scalar(3)
    else:
        with a.repeat(steps):            # way-span-padded step loop
            with a.repeat(chunks):
                a.vle(HR, ah, strides=(32, 0))
                a.vle(CR, aco, strides=(32, 0))
                a.vmul(HR, HR, CR)
                a.vle(XR, ax, strides=(32, pw * 4))
                a.vadd(HR, HR, XR)
                a.vse(HR, ah, strides=(32, 0))
                a.scalar(2)
            a.scalar(3)
    prog = a.finalize(mm)

    h = h0.astype(np.float64)
    for t in range(steps):
        h = coef.astype(np.float64) * h + X[t].astype(np.float64)
    return common.Built(prog, {"h": h.astype(np.float32)},
                        rtol=2e-4, atol=1e-5)


def scan_scalar_cost(steps=SCAN_STEPS, width=SCAN_WIDTH_CAP,
                     **_) -> ScalarCost:
    updates = steps * width
    return ScalarCost(flop_ops=2 * updates, loads=3 * updates,
                      stores=updates,
                      unique_lines=(2 * width + updates) // 8,
                      loop_iters=updates)


# ---------------------------------------------------------------------------
# attn tile (delegates to the mha kernel)
# ---------------------------------------------------------------------------


def build_attn(seq=ATTN_TILE["seq"], d=ATTN_TILE["d"], bc=ATTN_TILE["bc"],
               heads=ATTN_TILE["heads"], seed=0) -> common.Built:
    """Attention tile: the mha FlashAttention-2 emission with way-span
    padded head planes (certified fold of the head loop)."""
    return mha.build(seq=seq, d=d, bc=bc, heads=heads, seed=seed)


def attn_scalar_cost(**kw) -> ScalarCost:
    return mha.scalar_cost(**kw)


# ---------------------------------------------------------------------------
# tile policy
# ---------------------------------------------------------------------------

_BUILDERS = {"gemm": build_gemm, "scan": build_scan, "attn": build_attn}
_COSTS = {"gemm": gemm_scalar_cost, "scan": scan_scalar_cost,
          "attn": attn_scalar_cost}


def tile_for(op: LayerOp) -> tuple[str, dict, float]:
    """(kernel name, build kwargs, macro factor) for a LayerOp.

    The kernel name encodes the op's *real* shape — ops with equal
    signatures share a kernel (and, since the build kwargs are a function
    of the signature alone, an identical trace); ops with different
    signatures never merge.  The macro factor is real work / tile work at
    the TOKEN_BLOCK workload unit.
    """
    if op.kind == "gemm":
        k, n = op.shape
        kwargs = dict(tiles=TILES, mt=MT, k=min(k, K_CAP),
                      n=_round8(min(n, N_CAP)))
        name = f"net:gemm:{k}x{n}"
        tile_work = kwargs["tiles"] * kwargs["mt"] * kwargs["k"] * kwargs["n"]
    elif op.kind == "attn":
        heads, hd = op.shape
        kwargs = dict(ATTN_TILE)
        name = f"net:attn:{heads}h{hd}"
        tile_work = (2 * kwargs["seq"] * kwargs["seq"] * kwargs["d"]
                     * kwargs["heads"])
    elif op.kind == "scan":
        (width,) = op.shape
        kwargs = dict(steps=SCAN_STEPS, width=_round8(min(width,
                                                          SCAN_WIDTH_CAP)))
        name = f"net:scan:{width}"
        tile_work = kwargs["steps"] * kwargs["width"]
    else:
        raise ValueError(f"unknown LayerOp kind {op.kind!r}")
    return name, kwargs, op.work / tile_work


def builder_for(kind: str):
    return _BUILDERS[kind]


def cost_for(kind: str):
    return _COSTS[kind]
