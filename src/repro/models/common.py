"""Shared model components: norms, embeddings, RoPE/M-RoPE, sharding helper.

All layers are functional: ``init_*`` returns a params pytree, ``apply``
functions are pure.  Sharding is expressed through :func:`shard`, which
applies ``with_sharding_constraint`` against the ambient mesh set by the
launcher (:func:`set_mesh`); without a mesh it is a no-op so smoke tests and
single-device runs need no mesh plumbing.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_TLS = threading.local()

# Canonical logical axes:
#   batch  -> ("pod", "data")     sequence -> None (or "model" for long KV)
#   model-parallel (heads / ffn / vocab / experts) -> "model"
#   fsdp (param second axis) -> "data"
BATCH = ("pod", "data")
MODEL = "model"
FSDP = "data"


def set_mesh(mesh) -> None:
    _TLS.mesh = mesh


def get_mesh():
    return getattr(_TLS, "mesh", None)


def set_decode_layout(flag: bool) -> None:
    """Serving layout (EXPERIMENTS.md §Perf H2'): single-token activations
    are tiny (B,1,d); replicating them over the data axis lets every matmul
    against 2D-sharded weights run as a local partial contraction + a ~3 MB
    all-reduce, instead of re-gathering ~200 MB of weight shards per layer
    per token (the behaviour the partitioner picks when the batch axis is
    data-sharded).  Cache tensors keep their own explicit shardings."""
    _TLS.decode = flag


def in_decode_layout() -> bool:
    return getattr(_TLS, "decode", False)


@contextlib.contextmanager
def decode_layout():
    old = in_decode_layout()
    set_decode_layout(True)
    try:
        yield
    finally:
        set_decode_layout(old)


@contextlib.contextmanager
def mesh_context(mesh):
    old = get_mesh()
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(old)


def _axis_size(mesh, a) -> int:
    return mesh.shape[a]


def _filter_axes(mesh, axes, dim_size=None):
    """Drop axes not in the mesh; if ``dim_size`` is given, greedily drop
    trailing axes until the dimension divides evenly (auto-degradation keeps
    every (arch x shape) cell shardable: batch=1 long-context, odd vocabs
    like whisper's 51866, etc.)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    present = [a for a in axes if a in mesh.axis_names]
    if dim_size is not None:
        while present:
            prod = 1
            for a in present:
                prod *= _axis_size(mesh, a)
            if dim_size % prod == 0:
                break
            present.pop()
    if not present:
        return None
    return tuple(present) if len(present) > 1 else present[0]


def spec(mesh, *dims, shape=None) -> P:
    if shape is None:
        return P(*[_filter_axes(mesh, d) for d in dims])
    return P(*[_filter_axes(mesh, d, s) for d, s in zip(dims, shape)])


def shard(x, *dims):
    """Constrain ``x``'s sharding; dims are per-dimension axis (tuples) or
    None.  Axes absent from the ambient mesh are dropped and axes that do
    not divide the dimension are degraded; no mesh => no-op."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(dims) != x.ndim:
        raise ValueError(f"shard: {len(dims)} specs for rank-{x.ndim}")
    if (in_decode_layout() and x.ndim == 3 and x.shape[1] == 1
            and dims[0] == BATCH):
        # (B,1,d) activations: batch replicated; residual-stream d sharded
        # over the fsdp axis so matmuls against (d->data, f->model) weights
        # contract locally and emit small partial-sum all-reduces instead of
        # per-layer weight all-gathers.
        last = FSDP if dims[-1] is None else dims[-1]
        dims = (None,) + tuple(dims[1:-1]) + (last,)
    ns = NamedSharding(mesh, spec(mesh, *dims, shape=x.shape))

    # Bidirectional constraint (EXPERIMENTS.md §Perf H4): inside scanned +
    # rematerialised layers the backward cotangents have no sharding anchors
    # and the partitioner falls back to activation-sized all-gathers
    # (~230 GB/step measured on qwen3 train_4k).  Constraining each
    # activation's cotangent to the primal's sharding pins the whole
    # backward graph.
    @jax.custom_vjp
    def _pin(y):
        return jax.lax.with_sharding_constraint(y, ns)

    def _pin_fwd(y):
        return jax.lax.with_sharding_constraint(y, ns), None

    def _pin_bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, ns),)

    _pin.defvjp(_pin_fwd, _pin_bwd)
    return _pin(x)


def named_sharding(mesh, shape, *dims) -> NamedSharding:
    """NamedSharding with the same divisibility-aware degradation."""
    return NamedSharding(mesh, spec(mesh, *dims, shape=shape))


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (+ Qwen2-VL M-RoPE).
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


MROPE_FRACS = (0.25, 0.375, 0.375)        # temporal / height / width sections


def apply_mrope(x, positions3, theta: float = 1e4):
    """Qwen2-VL multimodal RoPE. x: (B,S,H,D); positions3: (3,B,S)."""
    d = x.shape[-1]
    half = d // 2
    sec = [int(half * f) for f in MROPE_FRACS]
    sec[-1] = half - sec[0] - sec[1]
    freqs = rope_freqs(d, theta)                       # (half,)
    parts = []
    start = 0
    for i, n in enumerate(sec):
        ang = (positions3[i][..., None].astype(jnp.float32)
               * freqs[start:start + n])               # (B,S,n)
        parts.append(ang)
        start += n
    ang = jnp.concatenate(parts, -1)[:, :, None, :]    # (B,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], -1)
    return jnp.asarray(out, jnp.float32)
