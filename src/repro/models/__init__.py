"""Model zoo: layers + unified assembly for the assigned architectures."""

from repro.models import attention, common, mlp, model, rglru, ssm
from repro.models.model import Model, get_model

__all__ = ["attention", "common", "mlp", "model", "rglru", "ssm",
           "Model", "get_model"]
