"""Mamba-1 selective SSM block (falcon-mamba-7b).

Training/prefill uses a two-level (chunked) time scan.  The (B, d_inner, n)
state tensors — dA, dBx — are formed *inside* the scan step from the
(B, d_inner) / (B, n) per-step projections, so nothing of size S x d_inner x
n is ever materialised (at train_4k scale that tensor would be ~550 GB).
The outer scan checkpoints chunk boundaries; inner-chunk states are
rematerialised in the backward pass.  Decode is a single recurrence step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import shard

CHUNK = 256


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 7)
    return {
        # Separate x/z projections: a fused (d, 2*din) matrix split along the
        # model-sharded output dim forces a cross-shard relayout (two
        # collective-permutes of the full activation per layer; §Perf H3b).
        "in_x": common.dense_init(ks[0], (d, din), dtype),
        "in_z": common.dense_init(jax.random.fold_in(ks[0], 1),
                                  (d, din), dtype),
        "conv_w": (0.1 * jax.random.normal(
            ks[1], (cfg.ssm_conv, din))).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": common.dense_init(ks[2], (din, dt_rank + 2 * n), dtype),
        "dt_proj": common.dense_init(ks[3], (dt_rank, din), dtype),
        "dt_bias": jnp.zeros((din,), jnp.float32),
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None], (din, 1))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": common.dense_init(ks[5], (din, d), dtype, fan_in=din),
    }


def _conv1d(p, x, prev_tail=None):
    """Causal depthwise conv along time. x: (B,S,din)."""
    w = p["conv_w"]                                   # (K, din)
    kk = w.shape[0]
    if prev_tail is None:
        pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    else:
        pad = prev_tail
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, din)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kk))
    return jax.nn.silu(out + p["conv_b"]), xp[:, -(kk - 1):]


def _step_projections(p, cfg, xc):
    """Per-step scan inputs (small tensors only).
    xc: (B,S,din) -> dt (B,S,din) f32, b_t/c_t (B,S,n) f32."""
    n = cfg.ssm_state
    dt_rank = max(cfg.d_model // 16, 1)
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"])
    dt_r, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])
    return dt, b_t.astype(jnp.float32), c_t.astype(jnp.float32)


def _recurrence(A, h, dt_t, b_t, c_t, xc_t):
    """One SSM step; forms (B,din,n) terms transiently.
    h: (B,din,n); dt_t/xc_t: (B,din); b_t/c_t: (B,n)."""
    dA = jnp.exp(dt_t[..., None] * A)                      # (B,din,n)
    dBx = (dt_t * xc_t)[..., None] * b_t[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, c_t)                   # (B,din)
    return h, y


def mamba(p, cfg, x, state=None):
    """Full-sequence Mamba block. x: (B,S,d). Returns (out, (conv_tail,
    ssm_state)) for decode continuation."""
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = shard(xs, common.BATCH, None, common.MODEL)
    z = shard(z, common.BATCH, None, common.MODEL)
    conv_tail = state[0] if state is not None else None
    xc, new_tail = _conv1d(p, xs, conv_tail)
    dt, b_t, c_t = _step_projections(p, cfg, xc)
    xc_f = xc.astype(jnp.float32)
    A = -jnp.exp(p["a_log"])                               # (din, n)

    h0 = (state[1] if state is not None else
          jnp.zeros((b, din, cfg.ssm_state), jnp.float32))
    h0 = shard(h0, common.BATCH, common.MODEL, None)

    pad = (-s) % CHUNK
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
        xc_f = jnp.pad(xc_f, ((0, 0), (0, pad), (0, 0)))
    nchunks = (s + pad) // CHUNK

    def to_chunks(t):                                      # (C,B,CHUNK,...)
        return (t.reshape(b, nchunks, CHUNK, -1)
                .transpose(1, 0, 2, 3))

    chunk_in = tuple(map(to_chunks, (dt, b_t, c_t, xc_f)))

    @jax.checkpoint
    def chunk_step(h, inputs):
        dtc, btc, ctc, xcc = inputs                        # (B,CHUNK,*)

        def step(hh, t):
            hh, y = _recurrence(A, hh, dtc[:, t], btc[:, t], ctc[:, t],
                                xcc[:, t])
            # Pin the state sharding: without this the partitioner
            # alternates layouts across timesteps, inserting two
            # collective-permutes per step (~527k collectives / 86 GB on
            # falcon-mamba train_4k; EXPERIMENTS.md §Perf H3).
            hh = shard(hh, common.BATCH, common.MODEL, None)
            return hh, y
        h, ys = jax.lax.scan(step, h, jnp.arange(CHUNK))
        return h, ys                                       # ys: (CHUNK,B,din)

    h_final, ys = jax.lax.scan(chunk_step, h0, chunk_in)
    y = ys.reshape(nchunks * CHUNK, b, din).transpose(1, 0, 2)[:, :s]
    y = y + p["d_skip"] * xc_f[:, :s]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, common.BATCH, None, common.MODEL)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return shard(out, common.BATCH, None, None), (new_tail, h_final)


def mamba_decode(p, cfg, x, state):
    """Single-token step. x: (B,1,d); state = (conv_tail (B,K-1,din),
    ssm_state (B,din,n))."""
    conv_tail, h = state
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xc, new_tail = _conv1d(p, xs, conv_tail)
    dt, b_t, c_t = _step_projections(p, cfg, xc)
    A = -jnp.exp(p["a_log"])
    h, y = _recurrence(A, h, dt[:, 0], b_t[:, 0], c_t[:, 0],
                       xc[:, 0].astype(jnp.float32))
    y = y[:, None] + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return shard(out, common.BATCH, None, None), (new_tail, h)
