"""Attention blocks: GQA/MQA (RoPE, M-RoPE, qk-norm, sliding window, cross)
and DeepSeek-V2 MLA (compressed latent KV).

Three entry modes share weights:
  * ``train/prefill``: full-sequence attention (optionally via the Pallas
    flash kernel when ``impl='pallas'`` — TPU target; ``xla`` path is used
    for dry-run lowering and CPU tests).
  * ``decode``: single-token step against a (possibly dispersed) KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import numpy as np_  # noqa: F401

from repro.models import common
from repro.models.common import shard

NEG_INF = -1e30


def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": common.dense_init(ks[0], (d, nq * hd), dtype),
        "wk": common.dense_init(ks[1], (d, nkv * hd), dtype),
        "wv": common.dense_init(ks[2], (d, nkv * hd), dtype),
        "wo": common.dense_init(ks[3], (nq * hd, d), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = common.init_rmsnorm(hd)
        p["k_norm"] = common.init_rmsnorm(hd)
    return p


def _project_qkv(p, cfg, x, positions, kv_input=None,
                 expand_kv: bool = False):
    """Returns q: (B,S,Hq,D), k/v: (B,Skv,Hkv,D) (rope applied).

    ``expand_kv`` (train/prefill): GQA KV heads are expanded to the full
    query head count *in the weight view* (repeat over the group axis;
    backprop sums group gradients, preserving GQA semantics exactly).  With
    fewer KV heads than the tensor-parallel axis (e.g. qwen3's 8 kv-heads on
    a 16-way model axis) the un-expanded KV activations cannot shard and XLA
    inserts a full activation all-gather per layer (~9.3 GB/layer measured
    on qwen3 train_4k) — expansion keeps every attention tensor head-sharded
    at ~3% extra projection FLOPs (see EXPERIMENTS.md §Perf, hypothesis H1).
    """
    b, s, _ = x.shape
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    kv_src = x if kv_input is None else kv_input
    skv = kv_src.shape[1]
    groups = hq // hkv
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    mesh = common.get_mesh()
    tp = mesh.shape.get(common.MODEL, 1) if mesh is not None else 1
    # Expand only as far as divisibility requires (e.g. kv8 on a 16-way
    # model axis -> 16 heads, not the full 32): halves the extra projection
    # FLOPs of the naive full expansion (§Perf H5).
    rep = 1
    if expand_kv and groups > 1 and hkv % tp != 0:
        # smallest group-divisor expansion that makes heads tp-divisible;
        # if none exists (e.g. 28 or 10 total heads on a 16-way axis) fall
        # back to no expansion — those archs shard on feature dims instead.
        rep = next((r for r in range(1, groups + 1)
                    if groups % r == 0 and (hkv * r) % tp == 0), 1)
    if rep > 1:
        wk = jnp.repeat(p["wk"].reshape(d, hkv, hd), rep, axis=1)
        wv = jnp.repeat(p["wv"].reshape(d, hkv, hd), rep, axis=1)
        wk = shard(wk, common.FSDP, common.MODEL, None)
        wv = shard(wv, common.FSDP, common.MODEL, None)
        k = jnp.einsum("bsd,dhe->bshe", kv_src, wk).reshape(b, skv, -1)
        v = jnp.einsum("bsd,dhe->bshe", kv_src, wv).reshape(b, skv, -1)
        hkv_eff = hkv * rep
        if cfg.attn_bias:
            k = k + jnp.repeat(p["bk"].reshape(hkv, hd), rep, 0).reshape(-1)
            v = v + jnp.repeat(p["bv"].reshape(hkv, hd), rep, 0).reshape(-1)
    else:
        k = jnp.einsum("bsd,dh->bsh", kv_src, p["wk"])
        v = jnp.einsum("bsd,dh->bsh", kv_src, p["wv"])
        hkv_eff = hkv
        if cfg.attn_bias:
            k, v = k + p["bk"], v + p["bv"]
    if cfg.attn_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, skv, hkv_eff, hd)
    v = v.reshape(b, skv, hkv_eff, hd)
    q = shard(q, common.BATCH, None, common.MODEL, None)
    k = shard(k, common.BATCH, None, common.MODEL, None)
    v = shard(v, common.BATCH, None, common.MODEL, None)
    if cfg.qk_norm:
        q = common.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = common.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.positional == "rope" and kv_input is None:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.positional == "mrope" and kv_input is None:
        q = common.apply_mrope(q, positions, cfg.rope_theta)
        k = common.apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, *, causal, window, q_offset=0):
    """XLA attention path. q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    qg = q.reshape(b, sq, hkv, groups, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attention(p, cfg, x, positions, *, causal=True, kv_input=None,
              impl="xla"):
    """Full-sequence attention (train / prefill). Returns (out, kv)."""
    q, k, v = _project_qkv(p, cfg, x, positions, kv_input, expand_kv=True)
    window = cfg.sliding_window
    if impl == "pallas":
        from repro.kernels import ops
        assert window is None and kv_input is None
        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
    else:
        out = _sdpa(q, k, v, causal=causal and kv_input is None,
                    window=window)
    b, s = x.shape[:2]
    out = jnp.einsum("bsh,hd->bsd",
                     out.reshape(b, s, cfg.num_heads * cfg.head_dim),
                     p["wo"])
    return shard(out, common.BATCH, None, None), (k, v)


def decode_attention(p, cfg, x, positions, cache_k, cache_v, cache_len):
    """One-token decode. x: (B,1,d); cache_k/v: (B,S_max,Hkv,D) with KV
    sharded (batch, seq->model).  Returns (out, new_k, new_v)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    # Flash-decode sharding (EXPERIMENTS.md §Perf H2): the per-token q/k/v
    # are tiny — replicate them over the model axis so attention against the
    # *sequence-sharded* cache is a local partial-softmax plus small
    # all-reduces, instead of re-gathering the multi-GB cache every layer.
    q = shard(q, common.BATCH, None, None, None)
    k = shard(k, common.BATCH, None, None, None)
    v = shard(v, common.BATCH, None, None, None)
    # For M-RoPE, positions is (3,B,1); the temporal component drives the
    # cache slot and causal validity.
    tpos = positions[0] if positions.ndim == 3 else positions
    b, _, hkv, d = k.shape
    smax = cache_k.shape[1]
    if cfg.sliding_window is not None and smax <= cfg.sliding_window:
        slot = tpos[:, 0] % smax                      # ring buffer
    else:
        slot = jnp.minimum(tpos[:, 0], smax - 1)
    oh = jax.nn.one_hot(slot, smax, dtype=k.dtype)    # (B, Smax)
    new_k = cache_k * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    new_v = cache_v * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * v
    new_k = shard(new_k, common.BATCH, common.MODEL, None, None)
    new_v = shard(new_v, common.BATCH, common.MODEL, None, None)

    groups = cfg.num_heads // hkv
    qg = q.reshape(b, hkv, groups, d)                 # (B,Hkv,G,D) (Sq=1)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        new_k.astype(jnp.float32)) * (d ** -0.5)
    kpos = jnp.arange(smax)[None, :]
    valid = kpos <= tpos[:, :1]                       # causal up to current
    if cfg.sliding_window is not None:
        if smax <= cfg.sliding_window:
            # Ring buffer: every written slot is in-window; once the ring has
            # wrapped, all slots are valid.
            valid = valid | (tpos[:, :1] >= smax)
        else:
            valid &= tpos[:, :1] - kpos < cfg.sliding_window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, new_v.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return shard(out, common.BATCH, None, None), new_k, new_v


def decode_cross_attention(p, cfg, x, enc_k, enc_v):
    """Cross-attention for enc-dec decode: enc K/V precomputed at prefill."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.attn_bias:
        q = q + p["bq"]
    q = q.reshape(b, 1, cfg.num_heads, cfg.head_dim)
    out = _sdpa(q, enc_k, enc_v, causal=False, window=None)
    out = jnp.einsum("bsh,hd->bsd",
                     out.reshape(b, 1, cfg.num_heads * cfg.head_dim),
                     p["wo"])
    return shard(out, common.BATCH, None, None)


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention (MLA).
# ---------------------------------------------------------------------------


def init_mla(key, cfg, dtype) -> dict:
    d = cfg.d_model
    h, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": common.dense_init(ks[0], (d, h * (dn + dr)), dtype),
        "wdkv": common.dense_init(ks[1], (d, r), dtype),        # compress
        "wkr": common.dense_init(ks[2], (d, dr), dtype),        # shared rope k
        "wuk": common.dense_init(ks[3], (r, h * dn), dtype),    # expand k
        "wuv": common.dense_init(ks[4], (r, h * dv), dtype),    # expand v
        "wo": common.dense_init(ks[5], (h * dv, d), dtype),
        "kv_norm": common.init_rmsnorm(r),
    }


def mla_attention(p, cfg, x, positions, *, causal=True):
    """Full-sequence MLA. Cache payload = (c_kv, k_rope): the paper-relevant
    point is that the latent (r + dr per token) is what a serving system
    stores — a compressed 'architectural register' the cVRF analogy caches.
    Returns (out, (c_kv, k_rope))."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = common.rmsnorm(p["kv_norm"],
                          jnp.einsum("bsd,dr->bsr", x, p["wdkv"]),
                          cfg.norm_eps)                       # (B,S,r)
    k_rope = common.apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["wkr"])[:, :, None, :],
        positions, cfg.rope_theta)                            # (B,S,1,dr)
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv,
                        p["wuk"]).reshape(b, s, h, dn)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["wuv"]).reshape(b, s, h, dv)

    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkod->bhqk", q_rope.astype(jnp.float32),
                           jnp.broadcast_to(
                               k_rope, (b, s, 1, dr)).astype(jnp.float32))
              ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    out = out.reshape(b, s, h * dv).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return shard(out, common.BATCH, None, None), (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, cfg, x, positions, cache_c, cache_kr, cache_len):
    """One-token MLA decode against the compressed latent cache.
    cache_c: (B,Smax,r); cache_kr: (B,Smax,dr)."""
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    smax = cache_c.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    c_new = common.rmsnorm(p["kv_norm"],
                           jnp.einsum("bsd,dr->bsr", x, p["wdkv"]),
                           cfg.norm_eps)[:, 0]                # (B,r)
    kr_new = common.apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["wkr"])[:, :, None, :],
        positions, cfg.rope_theta)[:, 0, 0]                   # (B,dr)
    slot = jnp.minimum(positions[:, 0], smax - 1)
    oh = jax.nn.one_hot(slot, smax, dtype=cache_c.dtype)
    cache_c = cache_c * (1 - oh[..., None]) + oh[..., None] * c_new[:, None]
    cache_kr = (cache_kr * (1 - oh[..., None])
                + oh[..., None] * kr_new[:, None])
    cache_c = shard(cache_c, common.BATCH, common.MODEL, None)
    cache_kr = shard(cache_kr, common.BATCH, common.MODEL, None)

    # Absorbed attention: q_nope projected into latent space once.
    wuk = p["wuk"].reshape(cfg.kv_lora_rank, h, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))               # (B,h,r)
    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat,
                         cache_c.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs",
                           q_rope[:, 0].astype(jnp.float32),
                           cache_kr.astype(jnp.float32))) * scale
    valid = jnp.arange(smax)[None] <= positions[:, :1]
    logits = jnp.where(valid[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, -1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs,
                     cache_c.astype(jnp.float32))             # (B,h,r)
    wuv = p["wuv"].reshape(cfg.kv_lora_rank, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx, wuv.astype(jnp.float32))
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return shard(out, common.BATCH, None, None), cache_c, cache_kr
