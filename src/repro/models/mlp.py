"""MLP blocks: SwiGLU / GELU and capacity-based Mixture-of-Experts.

MoE uses group-wise GShard-style routing with a fixed per-group capacity:
tokens are scatter-dispatched to (E, C) expert buffers via a sort-free rank
computation, expert FFNs run as batched einsums (experts sharded over the
``model`` mesh axis = expert parallelism), and results are combine-scattered
back with router weights.  Dropped tokens (over capacity) fall back to the
shared/residual path, as in GShard/Switch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import shard


def init_mlp(key, d: int, d_ff: int, dtype, kind: str = "swiglu") -> dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi": common.dense_init(ks[0], (d, d_ff), dtype),
            "wg": common.dense_init(ks[1], (d, d_ff), dtype),
            "wo": common.dense_init(ks[2], (d_ff, d), dtype, fan_in=d_ff),
        }
    return {
        "wi": common.dense_init(ks[0], (d, d_ff), dtype),
        "wo": common.dense_init(ks[2], (d_ff, d), dtype, fan_in=d_ff),
        "bi": jnp.zeros((d_ff,), dtype),
        "bo": jnp.zeros((d,), dtype),
    }


def mlp(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"])
    h = shard(h, common.BATCH, None, common.MODEL)
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    if kind != "swiglu":
        out = out + p["bo"]
    return shard(out, common.BATCH, None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts.
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": common.dense_init(ks[0], (d, e), jnp.float32),
        "wi": common.dense_init(ks[1], (e, d, ff), dtype),
        "wg": common.dense_init(ks[2], (e, d, ff), dtype),
        "wo": common.dense_init(ks[3], (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               cfg.moe_d_ff * cfg.num_shared_experts,
                               dtype, "swiglu")
    return p


def _dispatch_ranks(expert_ids, num_experts):
    """Per-(token,slot) rank within its expert, computed sort-free.

    expert_ids: (T, k) int32.  rank[t,j] = #assignments to the same expert
    strictly before flattened position t*k+j.  O(T*k*E) bool work.
    """
    t, k = expert_ids.shape
    flat = expert_ids.reshape(-1)                        # (T*k,)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot          # exclusive
    rank = jnp.take_along_axis(ranks, flat[:, None], 1)[:, 0]
    return rank.reshape(t, k)


def moe(p, cfg, x, capacity_factor: float = 1.25):
    """x: (B, S, d). Routing groups = batch rows (sharded over data)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    cap = max(int(capacity_factor * s * k / e), 1)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, k)                   # (B,S,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    def route_group(xg, idg, gateg):
        rank = _dispatch_ranks(idg, e)                    # (S,k)
        keep = rank < cap
        # Scatter tokens into (E, C, d) buffers.
        buf = jnp.zeros((e, cap, d), xg.dtype)
        tok = jnp.repeat(jnp.arange(s), k)
        buf = buf.at[idg.reshape(-1), jnp.where(
            keep.reshape(-1), rank.reshape(-1), cap - 1)].add(
            jnp.where(keep.reshape(-1)[:, None], xg[tok], 0))
        return buf, rank, keep

    buf, rank, keep = jax.vmap(route_group)(x, ids, gate)  # (B,E,C,d)
    buf = shard(buf, common.BATCH, common.MODEL, None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wi"])
    h = shard(h, common.BATCH, common.MODEL, None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"])
    out_buf = shard(out_buf, common.BATCH, common.MODEL, None, None)

    def combine_group(ob, idg, gateg, rankg, keepg):
        w = jnp.where(keepg, gateg, 0.0)                  # (S,k)
        gathered = ob[idg.reshape(-1),
                      jnp.minimum(rankg.reshape(-1), cap - 1)]
        gathered = gathered.reshape(s, k, d)
        return (w[..., None] * gathered.astype(jnp.float32)).sum(1)

    out = jax.vmap(combine_group)(out_buf, ids, gate, rank, keep)
    out = out.astype(x.dtype)
    if cfg.num_shared_experts:
        out = out + mlp(p["shared"], x, "swiglu")
    # Load-balancing auxiliary loss (Switch-style), returned for the trainer.
    density = jax.nn.one_hot(ids, e).mean((0, 1, 2))
    router_prob = probs.mean((0, 1))
    aux = e * jnp.sum(density * router_prob)
    return shard(out, common.BATCH, None, None), aux
