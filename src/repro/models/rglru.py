"""RG-LRU recurrent block (recurrentgemma-2b), per Griffin (arXiv:2402.19427).

Block = temporal conv1d + gated linear recurrence:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = a ** (c * r_t)                  (a = sigmoid(lambda), c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Same chunked-scan memory strategy as the Mamba block: the recurrent state
(B, width) is tiny — the architecture embodies the paper's small-working-set
premise (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import shard

CHUNK = 256
C_EXP = 8.0


def init_rglru(key, cfg, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        # separate projections (same relayout issue as ssm.in_proj)
        "in_x": common.dense_init(ks[0], (d, w), dtype),
        "in_z": common.dense_init(jax.random.fold_in(ks[0], 1),
                                  (d, w), dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (4, w))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": common.dense_init(ks[2], (w, w), dtype),
        "wx": common.dense_init(ks[3], (w, w), dtype),
        "ba": jnp.full((w,), 2.0, jnp.float32),     # init toward remembering
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 3.0, jnp.float32),    # a = sigmoid(lam) ~ 0.95
        "out_proj": common.dense_init(ks[5], (w, d), dtype),
    }


def _conv1d(p, x, prev_tail=None):
    w = p["conv_w"]
    kk = w.shape[0]
    pad = (jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
           if prev_tail is None else prev_tail)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kk))
    return out + p["conv_b"], xp[:, -(kk - 1):]


def _gates(p, xc):
    """xc: (B,S,w) -> log_a (B,S,w) f32, gated input (B,S,w) f32."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["wa"]
                                  ).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["wx"]
                                  ).astype(jnp.float32) + p["bx"])
    log_a = C_EXP * r * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xf)
    return a, gated


def rglru(p, cfg, x, state=None):
    """Full-sequence RG-LRU. x: (B,S,d). Returns (out, (conv_tail, h))."""
    b, s, d = x.shape
    w = cfg.lru_width or d
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = shard(xs, common.BATCH, None, common.MODEL)
    z = shard(z, common.BATCH, None, common.MODEL)
    conv_tail = state[0] if state is not None else None
    xc, new_tail = _conv1d(p, xs, conv_tail)
    a, gated = _gates(p, xc)

    h0 = (state[1] if state is not None else jnp.zeros((b, w), jnp.float32))
    pad = (-s) % CHUNK
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        gated = jnp.pad(gated, ((0, 0), (0, pad), (0, 0)))
    nchunks = (s + pad) // CHUNK
    a_c = a.reshape(b, nchunks, CHUNK, w).transpose(1, 0, 2, 3)
    g_c = gated.reshape(b, nchunks, CHUNK, w).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def chunk_step(h, inputs):
        ac, gc = inputs

        def step(hh, t):
            hh = ac[:, t] * hh + gc[:, t]
            return hh, hh
        return jax.lax.scan(step, h, jnp.arange(CHUNK))

    h_final, hs = jax.lax.scan(chunk_step, h0, (a_c, g_c))
    hs = hs.reshape(nchunks * CHUNK, b, w).transpose(1, 0, 2)[:, :s]
    y = (hs * jax.nn.gelu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, common.BATCH, None, common.MODEL)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"])
    return shard(out, common.BATCH, None, None), (new_tail, h_final)


def rglru_decode(p, cfg, x, state):
    """Single-token step. state = (conv_tail (B,3,w), h (B,w))."""
    conv_tail, h = state
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xc, new_tail = _conv1d(p, xs, conv_tail)
    a, gated = _gates(p, xc)
    h = a[:, 0] * h + gated[:, 0]
    y = (h[:, None] * jax.nn.gelu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"])
    return shard(out, common.BATCH, None, None), (new_tail, h)
