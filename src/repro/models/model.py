"""Unified model assembly for the ten assigned architectures.

One :class:`Model` per :class:`ArchConfig`; families share layer code:

  dense / vlm          : scan over [norm->GQA->norm->MLP] blocks
  moe                  : scan over [norm->GQA/MLA->norm->MoE] blocks
                         (+ leading dense layers, DeepSeek-style)
  ssm (falcon-mamba)   : scan over [norm->Mamba] blocks
  hybrid (r.gemma)     : unrolled (RG-LRU, RG-LRU, local-attn) pattern
  audio (whisper)      : encoder scan + decoder scan with cross-attention

Entry points (all pure):
  init(key)                          -> params
  train_logits(params, batch)        -> (logits, aux_loss)
  prefill(params, batch)             -> (logits, cache)
  decode_step(params, cache, batch)  -> (logits, cache)

Homogeneous stacks use stacked parameters + ``lax.scan`` over layers
(compile-time O(1) in depth); caches are stacked along the same leading
layer axis and scanned jointly.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention, common, mlp, rglru, ssm
from repro.models.common import shard

Params = Any


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _norm(cfg):
    if cfg.norm_kind == "layernorm":
        return common.init_layernorm, common.layernorm
    return common.init_rmsnorm, common.rmsnorm


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = _dtype(cfg)
        self.init_norm, self.apply_norm = _norm(cfg)

    # ------------------------------------------------------------- init --
    def _init_block(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {"norm1": self.init_norm(cfg.d_model)}
        if cfg.ssm:
            p["mixer"] = ssm.init_mamba(ks[0], cfg, self.dtype)
            return p                                   # mamba has fused mlp
        if cfg.mla:
            p["mixer"] = attention.init_mla(ks[0], cfg, self.dtype)
        else:
            p["mixer"] = attention.init_attention(ks[0], cfg, self.dtype)
        p["norm2"] = self.init_norm(cfg.d_model)
        if cfg.moe:
            p["ffn"] = mlp.init_moe(ks[1], cfg, self.dtype)
        else:
            p["ffn"] = mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                    self.dtype, cfg.mlp_kind)
        return p

    def _init_dense_block(self, key) -> dict:
        """Dense-FFN block for DeepSeek's leading layers."""
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        p = {"norm1": self.init_norm(cfg.d_model),
             "norm2": self.init_norm(cfg.d_model)}
        p["mixer"] = (attention.init_mla(ks[0], cfg, self.dtype) if cfg.mla
                      else attention.init_attention(ks[0], cfg, self.dtype))
        p["ffn"] = mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff, self.dtype,
                                "swiglu")
        return p

    def _init_rglru_block(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {"norm1": self.init_norm(cfg.d_model),
                "mixer": rglru.init_rglru(ks[0], cfg, self.dtype),
                "norm2": self.init_norm(cfg.d_model),
                "ffn": mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                    self.dtype, cfg.mlp_kind)}

    def _init_enc_block(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {"norm1": self.init_norm(cfg.d_model),
                "mixer": attention.init_attention(ks[0], cfg, self.dtype),
                "norm2": self.init_norm(cfg.d_model),
                "ffn": mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                    self.dtype, cfg.mlp_kind)}

    def _init_dec_block(self, key) -> dict:
        p = self._init_enc_block(key)
        cfg = self.cfg
        p["norm_x"] = self.init_norm(cfg.d_model)
        p["cross"] = attention.init_attention(
            jax.random.fold_in(key, 7), cfg, self.dtype)
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        kemb, khead, kblocks = jax.random.split(key, 3)
        params: dict = {
            "embed": shard(common.embed_init(kemb, cfg.vocab_size,
                                             cfg.d_model, self.dtype),
                           common.MODEL, common.FSDP),
            "final_norm": self.init_norm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = shard(
                common.dense_init(khead, (cfg.d_model, cfg.vocab_size),
                                  self.dtype),
                common.FSDP, common.MODEL)

        def stack(init_fn, n, key):
            keys = jax.random.split(key, n)
            return jax.vmap(init_fn)(keys)

        if cfg.encoder_decoder:
            k1, k2 = jax.random.split(kblocks)
            params["encoder"] = stack(self._init_enc_block,
                                      cfg.num_encoder_layers, k1)
            params["decoder"] = stack(self._init_dec_block,
                                      cfg.num_layers, k2)
            params["enc_norm"] = self.init_norm(cfg.d_model)
        elif cfg.hybrid:
            keys = jax.random.split(kblocks, cfg.num_layers)
            params["blocks"] = [
                (self._init_enc_block(keys[i]) if i % 3 == 2
                 else self._init_rglru_block(keys[i]))
                for i in range(cfg.num_layers)]
        elif cfg.moe and cfg.first_dense_layers:
            k1, k2 = jax.random.split(kblocks)
            params["dense_blocks"] = stack(self._init_dense_block,
                                           cfg.first_dense_layers, k1)
            params["blocks"] = stack(
                self._init_block, cfg.num_layers - cfg.first_dense_layers,
                k2)
        else:
            params["blocks"] = stack(self._init_block, cfg.num_layers,
                                     kblocks)
        return params

    # ------------------------------------------------------- embeddings --
    def _embed(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            # Modality stub: precomputed patch embeddings replace the token
            # embeddings at positions flagged by the frontend.
            mask = batch["vision_mask"][..., None]
            x = jnp.where(mask, batch["vision_embeds"].astype(x.dtype), x)
        if cfg.positional == "sinusoidal":
            x = x + _sinusoid_at(batch["positions"],
                                 cfg.d_model).astype(x.dtype)
        return shard(x, common.BATCH, None, None)

    def _positions(self, batch):
        if self.cfg.positional == "mrope":
            return batch["positions3"]
        return batch["positions"]

    # ------------------------------------------------------------ blocks --
    def _block_apply(self, p, x, positions, *, causal=True, window_every=None,
                     impl=None):
        """Standard (attn/mla + ffn) block; returns (x, kv, aux)."""
        cfg = self.cfg
        h = self.apply_norm(p["norm1"], x, cfg.norm_eps)
        if cfg.mla:
            att, kv = attention.mla_attention(p["mixer"], cfg, h, positions,
                                              causal=causal)
        else:
            att, kv = attention.attention(
                p["mixer"], cfg, h, positions, causal=causal,
                impl=impl or cfg.attn_impl)
        x = x + att
        h = self.apply_norm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe and "router" in p["ffn"]:
            out, aux = mlp.moe(p["ffn"], cfg, h)
        else:
            out, aux = mlp.mlp(p["ffn"], h, cfg.mlp_kind), 0.0
        return x + out, kv, aux

    # ------------------------------------------------------------- train --
    def train_logits(self, params, batch):
        """Full-sequence forward. Returns (logits, moe_aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = self._positions(batch)
        aux_total = 0.0

        if cfg.encoder_decoder:
            enc = batch["audio_embeds"].astype(self.dtype)
            enc = enc + _sinusoid_at(
                jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                 enc.shape[:2]), cfg.d_model
            ).astype(enc.dtype)
            enc = shard(enc, common.BATCH, None, None)

            @jax.checkpoint
            def enc_step(h, bp):
                h2, _, _ = self._block_apply(bp, h, positions, causal=False)
                return h2, None
            enc, _ = jax.lax.scan(enc_step, enc, params["encoder"])
            enc = self.apply_norm(params["enc_norm"], enc, cfg.norm_eps)

            @jax.checkpoint
            def dec_step(h, bp):
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                att, _ = attention.attention(bp["mixer"], cfg, hh, positions,
                                             causal=True)
                h = h + att
                hh = self.apply_norm(bp["norm_x"], h, cfg.norm_eps)
                xat, _ = attention.attention(bp["cross"], cfg, hh, positions,
                                             causal=False, kv_input=enc)
                h = h + xat
                hh = self.apply_norm(bp["norm2"], h, cfg.norm_eps)
                h = h + mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)
                return h, None
            x, _ = jax.lax.scan(dec_step, x, params["decoder"])

        elif cfg.ssm:
            @jax.checkpoint
            def blk(h, bp):
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                out, _ = ssm.mamba(bp["mixer"], cfg, hh)
                return h + out, None
            x, _ = jax.lax.scan(blk, x, params["blocks"])

        elif cfg.hybrid:
            for i, bp in enumerate(params["blocks"]):
                if i % 3 == 2:
                    x, _, _ = self._block_apply(bp, x, positions)
                else:
                    hh = self.apply_norm(bp["norm1"], x, cfg.norm_eps)
                    out, _ = rglru.rglru(bp["mixer"], cfg, hh)
                    x = x + out
                    hh = self.apply_norm(bp["norm2"], x, cfg.norm_eps)
                    x = x + mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)

        else:
            if cfg.moe and cfg.first_dense_layers:
                @jax.checkpoint
                def dense_blk(h, bp):
                    h2, _, _ = self._block_apply(bp, h, positions)
                    return h2, None
                x, _ = jax.lax.scan(dense_blk, x, params["dense_blocks"])

            @jax.checkpoint
            def blk(carry, bp):
                h, aux = carry
                h2, _, a = self._block_apply(bp, h, positions)
                return (h2, aux + a), None
            (x, aux_total), _ = jax.lax.scan(blk, (x, 0.0), params["blocks"])

        x = self.apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self._lm_head(params, x)
        return logits, aux_total

    def _lm_head(self, params, x):
        cfg = self.cfg
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        return shard(logits, common.BATCH, None, common.MODEL)

    # ----------------------------------------------------------- serving --
    def init_cache(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = self.dtype
        b = batch_size
        if cfg.encoder_decoder:
            l = cfg.num_layers
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            return {
                "k": jnp.zeros((l, b, max_len, hkv, hd), dt),
                "v": jnp.zeros((l, b, max_len, hkv, hd), dt),
                "ek": jnp.zeros((l, b, cfg.encoder_seq, hkv, hd), dt),
                "ev": jnp.zeros((l, b, cfg.encoder_seq, hkv, hd), dt),
            }
        if cfg.ssm:
            din = cfg.ssm_expand * cfg.d_model
            return {
                "conv": jnp.zeros((cfg.num_layers, b, cfg.ssm_conv - 1, din),
                                  dt),
                "h": jnp.zeros((cfg.num_layers, b, din, cfg.ssm_state),
                               jnp.float32),
            }
        if cfg.hybrid:
            w = cfg.lru_width or cfg.d_model
            n_att = sum(1 for i in range(cfg.num_layers) if i % 3 == 2)
            n_rec = cfg.num_layers - n_att
            wlen = min(max_len, cfg.sliding_window or max_len)
            return {
                "conv": jnp.zeros((n_rec, b, 3, w), dt),
                "h": jnp.zeros((n_rec, b, w), jnp.float32),
                "k": jnp.zeros((n_att, b, wlen, cfg.num_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((n_att, b, wlen, cfg.num_kv_heads,
                                cfg.head_dim), dt),
            }
        if cfg.mla:
            l = cfg.num_layers
            return {
                "c": jnp.zeros((l, b, max_len, cfg.kv_lora_rank), dt),
                "kr": jnp.zeros((l, b, max_len, cfg.qk_rope_dim), dt),
            }
        l = cfg.num_layers
        return {
            "k": jnp.zeros((l, b, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dt),
            "v": jnp.zeros((l, b, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dt),
        }

    def shard_cache(self, cache: dict) -> dict:
        """Apply the serving sharding policy: batch over data, long axes
        (sequence / d_inner) over model."""
        out = {}
        for k, v in cache.items():
            if k in ("k", "v"):          # (L,B,S,H,D): seq -> model
                out[k] = shard(v, None, common.BATCH, common.MODEL, None,
                               None)
            elif k in ("c", "kr"):
                out[k] = shard(v, None, common.BATCH, common.MODEL, None)
            elif k in ("ek", "ev"):
                out[k] = shard(v, None, common.BATCH, None, common.MODEL,
                               None)
            elif k == "conv":
                out[k] = shard(v, None, common.BATCH, None, common.MODEL)
            elif k == "h":
                out[k] = shard(v, None, common.BATCH, common.MODEL)
            else:
                out[k] = v
        return out

    def decode_step(self, params, cache, batch):
        """One-token decode. batch: tokens (B,1), positions (B,1) (or
        positions3 (3,B,1)), plus encoder state for enc-dec. Returns
        (logits (B,1,V), new_cache)."""
        with common.decode_layout():
            return self._decode_step(params, cache, batch)

    def _decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = self._positions(batch)
        new_cache = dict(cache)

        if cfg.encoder_decoder:
            def step(h, xs):
                bp, ck, cv, cek, cev = xs
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                att, nk, nv = attention.decode_attention(
                    bp["mixer"], cfg, hh, positions, ck, cv, None)
                h = h + att
                hh = self.apply_norm(bp["norm_x"], h, cfg.norm_eps)
                h = h + attention.decode_cross_attention(
                    bp["cross"], cfg, hh, cek, cev)
                hh = self.apply_norm(bp["norm2"], h, cfg.norm_eps)
                h = h + mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)
                return h, (nk, nv)
            x, (nk, nv) = jax.lax.scan(
                step, x, (params["decoder"], cache["k"], cache["v"],
                          cache["ek"], cache["ev"]))
            new_cache.update(k=nk, v=nv)

        elif cfg.ssm:
            def step(h, xs):
                bp, conv, hst = xs
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                out, (nc, nh) = ssm.mamba_decode(bp["mixer"], cfg, hh,
                                                 (conv, hst))
                return h + out, (nc, nh)
            x, (nc, nh) = jax.lax.scan(
                step, x, (params["blocks"], cache["conv"], cache["h"]))
            new_cache.update(conv=nc, h=nh)

        elif cfg.hybrid:
            ia = ir = 0
            ks, vs, convs, hs = [], [], [], []
            for i, bp in enumerate(params["blocks"]):
                hh = self.apply_norm(bp["norm1"], x, cfg.norm_eps)
                if i % 3 == 2:
                    att, nk, nv = attention.decode_attention(
                        bp["mixer"], cfg, hh, positions,
                        cache["k"][ia], cache["v"][ia], None)
                    x = x + att
                    ks.append(nk); vs.append(nv); ia += 1
                else:
                    out, (nc, nh) = rglru.rglru_decode(
                        bp["mixer"], cfg, hh,
                        (cache["conv"][ir], cache["h"][ir]))
                    x = x + out
                    convs.append(nc); hs.append(nh); ir += 1
                hh = self.apply_norm(bp["norm2"], x, cfg.norm_eps)
                x = x + mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)
            new_cache.update(k=jnp.stack(ks), v=jnp.stack(vs),
                             conv=jnp.stack(convs), h=jnp.stack(hs))

        elif cfg.mla:
            def step(carry, xs):
                h = carry
                bp, cc, ckr = xs
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                att, nc, nkr = attention.mla_decode(
                    bp["mixer"], cfg, hh, positions, cc, ckr, None)
                h = h + att
                hh = self.apply_norm(bp["norm2"], h, cfg.norm_eps)
                if cfg.moe:
                    out, _ = mlp.moe(bp["ffn"], cfg, hh)
                else:
                    out = mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)
                return h + out, (nc, nkr)

            off = cfg.first_dense_layers
            if off:
                def dstep(h, xs):
                    bp, cc, ckr = xs
                    hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                    att, nc, nkr = attention.mla_decode(
                        bp["mixer"], cfg, hh, positions, cc, ckr, None)
                    h = h + att
                    hh = self.apply_norm(bp["norm2"], h, cfg.norm_eps)
                    return h + mlp.mlp(bp["ffn"], hh, "swiglu"), (nc, nkr)
                x, (nc0, nkr0) = jax.lax.scan(
                    dstep, x, (params["dense_blocks"],
                               cache["c"][:off], cache["kr"][:off]))
            x, (nc, nkr) = jax.lax.scan(
                step, x, (params["blocks"], cache["c"][off:],
                          cache["kr"][off:]))
            if off:
                nc = jnp.concatenate([nc0, nc])
                nkr = jnp.concatenate([nkr0, nkr])
            new_cache.update(c=nc, kr=nkr)

        else:
            def step(carry, xs):
                h = carry
                bp, ck, cv = xs
                hh = self.apply_norm(bp["norm1"], h, cfg.norm_eps)
                att, nk, nv = attention.decode_attention(
                    bp["mixer"], cfg, hh, positions, ck, cv, None)
                h = h + att
                hh = self.apply_norm(bp["norm2"], h, cfg.norm_eps)
                if cfg.moe:
                    out, _ = mlp.moe(bp["ffn"], cfg, hh)
                else:
                    out = mlp.mlp(bp["ffn"], hh, cfg.mlp_kind)
                return h + out, (nk, nv)
            x, (nk, nv) = jax.lax.scan(
                step, x, (params["blocks"], cache["k"], cache["v"]))
            new_cache.update(k=nk, v=nv)

        x = self.apply_norm(params["final_norm"], x, cfg.norm_eps)
        return self._lm_head(params, x), new_cache

    def prefill(self, params, batch):
        """Full-prompt forward returning logits (prefill shapes lower this).

        For simplicity and dry-run purposes prefill shares train_logits
        (same compute); serving examples additionally materialise the cache
        via init_cache + per-token decode or the returned kv list."""
        return self.train_logits(params, batch)[0]


def _sinusoid_at(positions, d):
    """Sinusoidal embeddings for arbitrary integer positions (B,S)->(B,S,d)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.power(10000.0, -2.0 * i / d)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


@functools.lru_cache(maxsize=None)
def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
