from repro.optim import adamw
from repro.optim.adamw import OptConfig
__all__ = ["adamw", "OptConfig"]
