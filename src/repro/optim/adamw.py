"""AdamW with mixed precision (bf16 params, f32 master/moments), cosine
schedule, global-norm clipping, and optional error-feedback gradient
compression (int8) for cross-pod all-reduces.

No optax dependency: the optimizer is a pair of pure functions over pytrees
so its state shards exactly like the parameters (ZeRO-style: see
launch/train.py sharding rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False      # error-feedback int8 compression


def schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict[str, Any]:
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.zeros_like(x, jnp.float32), t)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": f32(params),
        "v": f32(params),
        "master": jax.tree.map(lambda x: x.astype(jnp.float32), params),
    }
    return state


def init_error_feedback(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)


# ---------------------------------------------------------------------------
# Error-feedback int8 gradient compression (beyond-paper distributed trick:
# quantise per-tensor before the cross-pod all-reduce; the residual is fed
# back into the next step so the bias telescopes away).
# ---------------------------------------------------------------------------


def compress_decompress(g, err):
    """Simulate int8 quantisation with error feedback. Returns
    (decompressed grad, new error)."""
    def one(gx, ex):
        gx = gx.astype(jnp.float32) + ex
        scale = jnp.maximum(jnp.max(jnp.abs(gx)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gx / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, gx - deq
    flat = jax.tree.map(one, g, err)
    return (jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple)))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: OptConfig, state, params, grads, err=None):
    """One AdamW step. Returns (new_params_bf16, new_state, new_err, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.compress_grads:
        assert err is not None
        grads, err = compress_decompress(grads, err)

    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(m, v, g, master):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    out = jax.tree.map(upd, state["m"], state["v"], grads, state["master"])
    unzip = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    m, v, master = unzip(0), unzip(1), unzip(2)
    new_params = jax.tree.map(
        lambda mm, p: mm.astype(p.dtype), master, params)
    new_state = {"step": step, "m": m, "v": v, "master": master}
    return new_params, new_state, err, {"grad_norm": gnorm, "lr": lr}
