"""Architecture config schema for the assigned model zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads

    # attention features
    positional: str = "rope"          # rope | mrope | sinusoidal | none
    rope_theta: float = 1e4
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: Optional[int] = None
    attn_impl: str = "xla"            # xla | pallas (TPU flash kernel)

    # MLA (DeepSeek-V2)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0       # leading dense layers (DeepSeek)

    # SSM (Mamba-1)
    ssm: bool = False
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # hybrid (RecurrentGemma): layer i is attention iff (i % 3 == 2)
    hybrid: bool = False
    lru_width: Optional[int] = None

    # encoder-decoder (Whisper)
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper 30s of mel frames

    # modality frontend stub ("input_specs provides precomputed embeddings")
    frontend: Optional[str] = None    # audio | vision

    mlp_kind: str = "swiglu"          # swiglu | gelu
    norm_kind: str = "rms"            # rms | layernorm
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    subquadratic: bool = False        # may run the long_500k shape

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        r = dict(
            num_layers=3 if self.hybrid else 2,
            d_model=128, num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=32, d_ff=256, vocab_size=512,
        )
        if self.encoder_decoder:
            r["num_encoder_layers"] = 2
            r["encoder_seq"] = 16
        if self.mla:
            r.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                     v_head_dim=32)
        if self.moe:
            r.update(num_experts=4, moe_top_k=2, moe_d_ff=64,
                     first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm:
            r.update(ssm_state=8, ssm_expand=2)
        if self.lru_width:
            r["lru_width"] = 128
        if self.sliding_window:
            r["sliding_window"] = 8
        return dataclasses.replace(self, **r)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, l = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.ssm:
            din = self.ssm_expand * d
            n = self.ssm_state
            dtr = max(d // 16, 1)
            blk = (d * 2 * din + self.ssm_conv * din
                   + din * (dtr + 2 * n) + dtr * din + din * n + din * d)
            return emb + l * blk
        if self.mla:
            h = self.num_heads
            attn = (d * h * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * self.kv_lora_rank + d * self.qk_rope_dim
                    + self.kv_lora_rank * h * (self.qk_nope_dim
                                               + self.v_head_dim)
                    + h * self.v_head_dim * d)
        else:
            hd = self.head_dim
            attn = d * hd * (self.num_heads * 2
                             + self.num_kv_heads * 2)
        ff_mult = 3 if self.mlp_kind == "swiglu" else 2
        dense_ff = ff_mult * d * self.d_ff
        if self.moe:
            moe_ff = (self.num_experts * 3 * d * self.moe_d_ff
                      + self.num_shared_experts * 3 * d * self.moe_d_ff
                      + d * self.num_experts)
            n_moe = l - self.first_dense_layers
            ff_total = self.first_dense_layers * dense_ff + n_moe * moe_ff
        else:
            ff_total = l * dense_ff
        if self.hybrid:
            w = self.lru_width or d
            n_att = l // 3
            n_rec = l - n_att
            rec = d * 2 * w + 4 * w + 2 * w * w + w * d
            return emb + n_att * (attn + dense_ff) + n_rec * (rec + dense_ff)
        layers = l * attn + ff_total
        if self.encoder_decoder:
            layers += self.num_encoder_layers * (attn + dense_ff) + l * attn
        return emb + layers

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        inactive = ((self.num_experts - self.moe_top_k) * 3
                    * self.d_model * self.moe_d_ff
                    * (self.num_layers - self.first_dense_layers))
        return full - inactive
