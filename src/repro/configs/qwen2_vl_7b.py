"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import QWEN2_VL_7B as CONFIG

CONFIG = CONFIG
