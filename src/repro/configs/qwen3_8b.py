"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import QWEN3_8B as CONFIG

CONFIG = CONFIG
