"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import FALCON_MAMBA_7B as CONFIG

CONFIG = CONFIG
