"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import WHISPER_LARGE_V3 as CONFIG

CONFIG = CONFIG
