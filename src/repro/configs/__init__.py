"""Architecture + shape configs for the assigned model zoo."""

from repro.configs.base import ArchConfig
from repro.configs.registry import (ARCHS, SHAPES, ShapeConfig,
                                    cell_runnable, get)

__all__ = ["ArchConfig", "ARCHS", "SHAPES", "ShapeConfig",
           "cell_runnable", "get"]
