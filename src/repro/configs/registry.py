"""The ten assigned architectures (exact configs from the assignment) plus
the shape grid.  ``get(name)`` / ``ARCHS`` are the public entry points."""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig

ARCHS: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- SSM -------------------------------------------------------------------
FALCON_MAMBA_7B = _reg(ArchConfig(
    name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=65024,
    positional="none", ssm=True, ssm_state=16, ssm_conv=4, ssm_expand=2,
    mlp_kind="swiglu", subquadratic=True))

# --- audio enc-dec ---------------------------------------------------------
WHISPER_LARGE_V3 = _reg(ArchConfig(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    positional="sinusoidal", attn_bias=True, encoder_decoder=True,
    num_encoder_layers=32, encoder_seq=1500, frontend="audio",
    mlp_kind="gelu", norm_kind="layernorm"))

# --- hybrid ----------------------------------------------------------------
RECURRENTGEMMA_2B = _reg(ArchConfig(
    name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
    num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680,
    vocab_size=256000, hybrid=True, lru_width=2560, sliding_window=2048,
    mlp_kind="gelu", tie_embeddings=True, subquadratic=True))

# --- VLM -------------------------------------------------------------------
QWEN2_VL_7B = _reg(ArchConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    positional="mrope", attn_bias=True, frontend="vision",
    rope_theta=1e6))

# --- dense -----------------------------------------------------------------
PHI3_MINI = _reg(ArchConfig(
    name="phi3-mini-3.8b", family="dense", num_layers=32, d_model=3072,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32064))

GRANITE_8B = _reg(ArchConfig(
    name="granite-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=49152))

COMMAND_R_PLUS = _reg(ArchConfig(
    name="command-r-plus-104b", family="dense", num_layers=64,
    d_model=12288, num_heads=96, num_kv_heads=8, d_ff=33792,
    vocab_size=256000, rope_theta=75e4))

QWEN3_8B = _reg(ArchConfig(
    name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=12288,
    vocab_size=151936, qk_norm=True, rope_theta=1e6))

# --- MoE -------------------------------------------------------------------
DEEPSEEK_V2_LITE = _reg(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", num_layers=27, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=10944, vocab_size=102400,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, moe=True, num_experts=64, num_shared_experts=2,
    moe_top_k=6, moe_d_ff=1408, first_dense_layers=1))

PHI35_MOE = _reg(ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    moe=True, num_experts=16, moe_top_k=2, moe_d_ff=6400,
    norm_kind="layernorm"))


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


# ---------------------------------------------------------------------------
# Shape grid (assignment): every arch x every shape = one dry-run cell.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_runnable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell per assignment rules.

    ``long_500k`` needs sub-quadratic attention: run for SSM/hybrid, skip for
    pure full-attention archs (documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("full-attention arch: 500k dense-KV decode is "
                       "quadratic-state; skipped per assignment rules")
    return True, ""
