"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import COMMAND_R_PLUS as CONFIG

CONFIG = CONFIG
