"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import PHI3_MINI as CONFIG

CONFIG = CONFIG
