"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import RECURRENTGEMMA_2B as CONFIG

CONFIG = CONFIG
