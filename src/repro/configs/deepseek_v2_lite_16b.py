"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import DEEPSEEK_V2_LITE as CONFIG

CONFIG = CONFIG
