"""The paper's own machine configuration (Table 1) as a config module:
Codasip L31 (RV32IMFCB, 3-stage, 200 MHz) + 256-bit / 8-lane VPU.

Used by the simulator defaults and the benchmark harness; exposed here so
the paper target sits beside the assigned LM architectures.
"""

from repro.core.isa import (MASK_REG, NUM_ARCH_VREGS, VL_ELEMS, VLEN_BITS,
                            VLEN_BYTES)
from repro.core.simulator import DEFAULT_MACHINE, MachineParams, MachineSweep

L31_VPU = DEFAULT_MACHINE                 # L1D 16 KB 2-way, mem 5 cyc
CVRF_SIZES = (3, 4, 5, 6, 7, 8, 16)       # the paper's evaluated heights
FULL_VRF = NUM_ARCH_VREGS                 # 32 architectural registers
PAPER_CVRF = 8                            # the headline configuration

# Table 1 gives the memory latency as a 1-5 cycle range: the whole range as
# one traced sweep axis (one compiled executable for all five points).
TABLE1_MEM_RANGE = MachineSweep.make((1, 2, 3, 4, 5))

__all__ = ["L31_VPU", "CVRF_SIZES", "FULL_VRF", "PAPER_CVRF",
           "TABLE1_MEM_RANGE", "MachineParams", "MachineSweep", "MASK_REG",
           "NUM_ARCH_VREGS", "VL_ELEMS", "VLEN_BITS", "VLEN_BYTES"]
