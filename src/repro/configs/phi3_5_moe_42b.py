"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import PHI35_MOE as CONFIG

CONFIG = CONFIG
