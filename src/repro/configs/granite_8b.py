"""Assigned architecture config (see registry.py for fields)."""

from repro.configs.registry import GRANITE_8B as CONFIG

CONFIG = CONFIG
