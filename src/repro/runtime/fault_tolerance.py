"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment every host runs a :class:`Heartbeat`
reporting step progress; the coordinator applies :class:`StragglerPolicy`
(flag hosts whose step latency exceeds median x threshold; evict after K
strikes and trigger an elastic restart from the latest checkpoint).  In this
single-process container the same objects drive the control flow — the
trainer consults them every step and the restart path is exercised by tests
(kill -> restore -> bit-identical continuation, see tests/test_trainer.py).
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HeartbeatRecord:
    host: int
    step: int
    t: float
    step_time: float


class Heartbeat:
    """Per-host liveness + step-latency reporting."""

    def __init__(self, host_id: int = 0):
        self.host = host_id
        self._last = time.monotonic()
        self.records: list[HeartbeatRecord] = []

    def beat(self, step: int, now: float | None = None,
             step_time: float | None = None) -> HeartbeatRecord:
        """Record a beat.  With no arguments the wall clock is read (the
        trainer path); a virtual-time caller (the serving engine) passes
        ``now``/``step_time`` explicitly so detection stays deterministic."""
        if now is None:
            now = time.monotonic()
        if step_time is None:
            step_time = now - self._last
        rec = HeartbeatRecord(self.host, step, now, step_time)
        self._last = now
        self.records.append(rec)
        if len(self.records) > 1000:
            del self.records[:500]
        return rec


@dataclasses.dataclass
class StragglerPolicy:
    """Median-based straggler detection with strike accumulation."""

    threshold: float = 2.0            # x median step time
    strikes_to_evict: int = 3
    window: int = 20

    def __post_init__(self):
        self._strikes: dict[int, int] = {}

    def observe(self, records: list[HeartbeatRecord]) -> dict[int, str]:
        """Returns {host: 'ok'|'straggler'|'evict'} for the latest window."""
        if not records:
            return {}
        recent = records[-self.window:]
        times = sorted(r.step_time for r in recent)
        median = times[len(times) // 2]
        verdict = {}
        last_by_host: dict[int, HeartbeatRecord] = {}
        for r in recent:
            last_by_host[r.host] = r
        for host, r in last_by_host.items():
            if median > 0 and r.step_time > self.threshold * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                verdict[host] = ("evict" if self._strikes[host]
                                 >= self.strikes_to_evict else "straggler")
            else:
                self._strikes[host] = 0
                verdict[host] = "ok"
        return verdict


@dataclasses.dataclass
class RestartPolicy:
    """Bounded exponential-backoff restart budget."""

    max_restarts: int = 10
    backoff_base: float = 1.0
    backoff_cap: float = 60.0

    def __post_init__(self):
        self.restarts = 0

    def next_delay(self) -> float | None:
        """None => restart budget exhausted, fail the job."""
        if self.restarts >= self.max_restarts:
            return None
        delay = min(self.backoff_base * (2 ** self.restarts),
                    self.backoff_cap)
        self.restarts += 1
        return delay
