from repro.runtime.fault_tolerance import (Heartbeat, RestartPolicy,
                                           StragglerPolicy)
__all__ = ["Heartbeat", "RestartPolicy", "StragglerPolicy"]
