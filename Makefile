# Developer entry points.  The tier-1 gate is `make test-fast` (the pytest
# default: everything not marked `slow`, kept under ~3 minutes including the
# differential conformance matrix); `make test` adds the paper-size sweeps
# and the exhaustive (program, capacity, machine) grids; `make docs-check`
# executes the README quickstart block and examples/quickstart.py.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
PYTEST = PYTHONPATH=$(PYTHONPATH) python -m pytest

.PHONY: test-fast test bench bench-smoke serve-smoke roofline-smoke \
	docs-check

test-fast:
	$(PYTEST) -x -q

test:
	$(PYTEST) -x -q -m ""

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json BENCH_core.json

# Schema guard: the full front door (suites, --kernels subsetting, schema-4
# JSON with metric metadata) on a 2-kernel subset in a couple of minutes.
bench-smoke: serve-smoke roofline-smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_smoke.json --kernels dropout,gemv \
	  fig2 table3 fig6 fig8 pareto

# Serving-side schema guard: kv_dispersion + the serving SLO suite on the
# smoke grid (2 hot-pool sizes, tiny scenario) under a tight event budget.
serve-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_serve_smoke.json --max-events 120 \
	  kv_dispersion serving_slo

# Roofline regression guard: the measured Pallas suite on the smoke grid
# must record >0 rows and >0 dispatches with the per-point measured/model
# payload present — the suite can never silently regress to 0 rows again.
roofline-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_roofline_smoke.json --max-events 120 roofline
	PYTHONPATH=$(PYTHONPATH) python -c "import json; \
	  r = json.load(open('BENCH_roofline_smoke.json'))['suites']['roofline']; \
	  assert r['rows'] > 0 and r['dispatches'] > 0, r; \
	  assert r['extra']['rows'] and all('model_agree' in p \
	    for p in r['extra']['rows']), r['extra']; \
	  print('roofline smoke OK:', r['rows'], 'rows,', \
	        r['dispatches'], 'dispatches')"

docs-check:
	$(PYTEST) -x -q tests/test_docs.py
