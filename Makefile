# Developer entry points.  The tier-1 gate is `make test-fast` (the pytest
# default: everything not marked `slow`, kept under ~3 minutes including the
# differential conformance matrix); `make test` adds the paper-size sweeps
# and the exhaustive (program, capacity, machine) grids; `make docs-check`
# executes the README quickstart block and examples/quickstart.py.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
PYTEST = PYTHONPATH=$(PYTHONPATH) python -m pytest

# Fast-tier wall-clock budget (seconds).  The suite must stay within it so
# a growing program population (the trace-from-model bridge multiplies
# registered kernels) cannot silently inflate tier-1; `timeout` fails the
# target loudly instead.  Sized from the measured full fast-tier wall on
# CI-class hardware with headroom for cold JIT compiles.
TEST_BUDGET_SECS ?= 900

.PHONY: test-fast test bench bench-smoke serve-smoke roofline-smoke \
	network-smoke cluster-smoke dse-smoke docs-check

test-fast:
	timeout $(TEST_BUDGET_SECS) $(PYTEST) -x -q

test:
	$(PYTEST) -x -q -m ""

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json BENCH_core.json

# Schema guard: the full front door (suites, --kernels subsetting, schema-5
# JSON with metric metadata) on a 2-kernel subset in a couple of minutes.
bench-smoke: serve-smoke roofline-smoke network-smoke cluster-smoke dse-smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_smoke.json --kernels dropout,gemv \
	  fig2 table3 fig6 fig8 pareto

# Cluster regression guard: the multi-core dispersion suite on a reduced
# grid.  Asserts rows present, cluster-engine compiles bounded by the
# (bucket x L1 geometry x cores) plan groups, and an N=1 row identical to
# a fresh single-core Session.run at the same point (the passthrough pin,
# exercised through the whole benchmark + JSON path).
cluster-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_cluster_smoke.json --kernels dropout \
	  --max-events 4000 cluster_sweep
	PYTHONPATH=$(PYTHONPATH) python -c "import json; \
	  from repro import api; \
	  r = json.load(open('BENCH_cluster_smoke.json'))['suites']['cluster_sweep']; \
	  x = r['extra']; \
	  assert r['rows'] > 0, r; \
	  assert x['compiles'] <= x['plan_groups'], x; \
	  row = [t for t in x['rows'] if t['cores'] == 1][0]; \
	  res = api.Session().run(api.Sweep(kernels=[row['name']], \
	    capacity=[row['capacity']], \
	    l1_geometry=[api.L1Geometry.from_kbytes(row['l1_kb'])], \
	    max_events=4000)); \
	  assert int(res.value('cycles', capacity=row['capacity'])) \
	    == row['cycles'], row; \
	  print('cluster smoke OK:', r['rows'], 'rows,', x['compiles'], \
	        'compiles /', x['plan_groups'], 'plan groups, N=1 identity holds')"

# Silicon DSE regression guard: the 3-objective macro-model driver on a
# reduced grid.  The schema-7 JSON must carry a non-empty front per macro
# model with the arXiv:2410.08396 external baseline labeled on it, and
# cluster-engine compiles bounded by the (bucket x geometry x cores) plan
# groups.
dse-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_dse_smoke.json --kernels dropout \
	  --max-events 4000 dse
	PYTHONPATH=$(PYTHONPATH) python -c "import json; \
	  rep = json.load(open('BENCH_dse_smoke.json')); \
	  assert rep['schema'] == 7, rep['schema']; \
	  assert {'flop', 'sram6t', 'table'} <= set(rep['macro_models']); \
	  r = rep['suites']['dse']; x = r['extra']; \
	  assert r['rows'] > 0, r; \
	  assert x['compiles'] <= x['plan_groups'], x; \
	  fronts = [x['fronts'][m]['dropout'] for m in ('flop', 'sram6t', 'table')]; \
	  assert all(fronts), [len(f) for f in fronts]; \
	  assert all(any(p.get('external') and p['source'] == 'arXiv:2410.08396' \
	    for p in f) for f in fronts), 'external baseline missing'; \
	  print('dse smoke OK:', r['rows'], 'rows,', \
	        [len(f) for f in fronts], 'front points,', x['compiles'], \
	        'compiles /', x['plan_groups'], 'plan groups')"

# Network-bridge regression guard: whole registry models lowered through
# repro.bridge on the truncation grid.  The JSON must record >0 rows, the
# lowered-network summaries, and a compile count no larger than the number
# of (shape bucket x L1 geometry) plan groups.
network-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_network_smoke.json --max-events 120 network_sweep
	PYTHONPATH=$(PYTHONPATH) python -c "import json; \
	  r = json.load(open('BENCH_network_smoke.json'))['suites']['network_sweep']; \
	  x = r['extra']; \
	  assert r['rows'] > 0 and x['networks'], r; \
	  assert x['compiles'] <= x['plan_groups'], x; \
	  print('network smoke OK:', r['rows'], 'rows,', len(x['networks']), \
	        'models,', x['compiles'], 'compiles /', x['plan_groups'], \
	        'plan groups')"

# Serving-side schema guard: kv_dispersion + the serving SLO suite on the
# smoke grid (2 hot-pool sizes, tiny scenario) under a tight event budget.
serve-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_serve_smoke.json --max-events 120 \
	  kv_dispersion serving_slo

# Roofline regression guard: the measured Pallas suite on the smoke grid
# must record >0 rows and >0 dispatches with the per-point measured/model
# payload present — the suite can never silently regress to 0 rows again.
roofline-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
	  --json BENCH_roofline_smoke.json --max-events 120 roofline
	PYTHONPATH=$(PYTHONPATH) python -c "import json; \
	  r = json.load(open('BENCH_roofline_smoke.json'))['suites']['roofline']; \
	  assert r['rows'] > 0 and r['dispatches'] > 0, r; \
	  assert r['extra']['rows'] and all('model_agree' in p \
	    for p in r['extra']['rows']), r['extra']; \
	  print('roofline smoke OK:', r['rows'], 'rows,', \
	        r['dispatches'], 'dispatches')"

docs-check:
	$(PYTEST) -x -q tests/test_docs.py
