"""Clustered vector units: cores x cVRF capacity x L1 geometry at a fixed
SRAM budget.

The paper makes one vector unit cheap; Spatz (arXiv:2309.10137) asks what
happens when you cluster many behind shared memory.  This suite answers
the ROADMAP question "given a fixed total SRAM budget, how do cores x
cVRF-capacity x L1 trade off?" with the fused cluster engine
(:mod:`repro.cluster`): every (kernel, capacity, L1 geometry, cores)
point runs N lockstep dispersion cores behind a shared L2 + banked
memory channels as ONE declarative ``Session.run`` — one cluster-engine
compile per (shape bucket, L1 geometry, cores) plan group, pinned by
``tests/test_cluster.py``.

Reported per point: cluster makespan cycles, the contention stall ratio,
and the three budget axes — ``sram_budget_bytes`` (total storage bits the
cluster holds: per-core cVRF + L1, plus the shared L2),
``cluster_area`` (logic + macro au) and ``aggregate_throughput`` (summed
useful writes per makespan cycle).  The headline output is the
**iso-budget Pareto front** per kernel: the (cores, capacity, L1) points
no other point beats on both storage budget and throughput — many small
cores with dispersed cVRFs vs few big-VRF cores on one curve
(``run.py --json`` schema 6, ``extra.iso_budget_front``).
"""

from __future__ import annotations

from benchmarks import common
from repro import api
from repro.cluster import ClusterConfig

KERNELS = ("gemv", "dropout", "flashattention2")
CORES = (1, 2, 4, 8)
CAPS = (3, 4, 8)
L1_KBYTES = (4, 16)
# Shared memory system: 32 KB L2 (256 sets x 4 ways x 32 B), two banked
# memory channels — kept fixed so the budget axis varies only through the
# per-core choices.
CLUSTER = ClusterConfig(l2_sets=256, l2_ways=4, mem_channels=2)

_LAST_EXTRA: dict = {}


def run(names=KERNELS, cores=CORES, caps=CAPS, l1_kbytes=L1_KBYTES,
        cluster=CLUSTER, kernel_params="paper", max_events=None,
        fold=True, session=None) -> list[dict]:
    ses = session or api.default_session()
    sweep = api.Sweep(
        kernels=tuple(names), capacity=tuple(caps),
        l1_geometry=tuple(api.L1Geometry.from_kbytes(kb)
                          for kb in l1_kbytes),
        cores=tuple(cores), cluster=cluster,
        kernel_params=kernel_params, fold=fold, max_events=max_events)
    res, dt = common.timed(ses.run, sweep)
    res = (res.derive("scaled_cycles").derive("sram_budget_bytes")
              .derive("cluster_area").derive("aggregate_throughput")
              .derive("contention_stall_ratio"))
    rows = res.to_rows([
        "cycles", "scaled_cycles", "contention_stalls", "l2_hits",
        "l2_misses", "core_cycles_sum", "sram_budget_bytes",
        "cluster_area", "aggregate_throughput", "contention_stall_ratio"])
    us_each = dt * 1e6 / max(1, len(rows))
    for r in rows:
        r["name"] = r.pop("kernel")
        r["us_per_call"] = round(us_each, 1)
    fronts = {
        name: res.pareto("sram_budget_bytes", "aggregate_throughput",
                         maximize=("aggregate_throughput",), kernel=name)
        for name in sweep.kernels}
    iso_area = {
        name: res.pareto("cluster_area", "aggregate_throughput",
                         maximize=("aggregate_throughput",), kernel=name)
        for name in sweep.kernels}
    plan = res.meta["plan"]
    fe = res.data["fold_exact"]
    _LAST_EXTRA.clear()
    _LAST_EXTRA.update(
        cluster=res.meta["cluster"],
        points=res.meta["points"], compiles=res.meta["compiles"],
        dispatches=res.meta["dispatches"],
        plan_groups=len({(g["l1_geometry"], g["bucket"], g["cores"])
                         for g in plan}),
        fold_exact_fraction=float(fe.mean()),
        iso_budget_front=fronts,
        iso_area_front=iso_area,
        rows=rows,
    )
    return rows


def main(names=KERNELS, max_events: int | None = None) -> list[dict]:
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "cores", "capacity", "l1_kb",
                       "cycles", "contention_stall_ratio",
                       "sram_budget_bytes", "aggregate_throughput"])
    front = _LAST_EXTRA["iso_budget_front"]
    print("# iso-budget Pareto front (budget_bytes -> best throughput):")
    for name, rows_f in front.items():
        pts = ", ".join(
            f"{r['sram_budget_bytes']:.0f}B:N{r['cores']}/c{r['capacity']}"
            f"/L1-{r['l1_kb']}KB" for r in rows_f)
        print(f"#   {name}: {pts}")
    return rows


def json_extra() -> dict:
    """Cluster payload for ``run.py --json`` (schema >= 6): the shared
    memory system, plan/compile accounting, per-point rows and the
    iso-budget / iso-area Pareto fronts per kernel."""
    return dict(_LAST_EXTRA)


if __name__ == "__main__":
    main()
