"""3-objective design-space exploration: area x cycles x energy over
cVRF capacity x L1 geometry x cores, per silicon macro model.

The Pareto-frontier and cluster suites each trade TWO quantities; real
sizing decisions juggle three — silicon area, makespan cycles and
application energy — and the answer depends on what silicon the SRAM
macros are priced in.  This driver walks the whole design space (cVRF
capacity incl. the full-32 VRF, L1 size, core count behind a shared L2)
as ONE declarative ``Session.run`` through the cluster engine, then
re-prices the grid under each registered :mod:`repro.silicon` macro
model and emits the **maximal 3-objective front** (``silicon_cluster_
area``, ``scaled_cycles``, ``silicon_energy``) per kernel per model via
the N-objective ``SweepResult.pareto(axes=[...])``.

Every front point carries provenance: the macro model that priced it,
the (cores, capacity, L1) geometry, its fold certificate and the
compile-plan group (bucket x geometry x cores) that produced its
counters.  The reduced-register RVV design of arXiv:2410.08396 — 16
architectural registers, full-VRF hardware, compiler register allocation
reported at near-zero performance loss — rides on each front as a
labeled **external baseline** point: its logic area is
``cpu_area(16, dispersed=False)``, its L1 macro is priced by the same
macro model, and its cycles/energy are taken from this sweep's
capacity-32 single-core point (the near-zero-loss assumption, recorded
on the point itself).

The headline finding is the **iso-area winner flip**: the ``flop``
backend's flat periphery makes small L1 macros unrealistically cheap, so
a dispersed core with a bigger L1 can undercut a full-VRF core with a
small L1 on area; under ``sram6t``'s edge-scaled periphery the ordering
reverses and the 2-objective (area, cycles) front membership changes —
``extra.iso_area_winners`` lists exactly which configurations enter or
leave each front.  ``run.py --json`` schema 7 carries all of it
(``extra.fronts`` / ``external_baseline`` / ``iso_area_winners`` +
the ``macro_models`` catalog).

Multi-core note: the lockstep cluster runs the *same* program on every
core, so at fixed per-core work more cores buy area/energy without
cutting makespan — multi-core points are mostly dominated on this front
(they win on ``aggregate_throughput``, the cluster suite's axis, not on
latency).  They stay in the grid so the front can prove that, not assume
it.
"""

from __future__ import annotations

from benchmarks import common
from repro import api, silicon
from repro.cluster import ClusterConfig
from repro.core import costmodel

KERNELS = ("gemv", "dropout", "flashattention2")
CORES = (1, 2, 4)
# 3/4/8 dispersed cVRF capacities plus the full-32 VRF reference point
# (dispersed="auto" turns the mechanism off at 32).
CAPS = (3, 4, 8, 32)
L1_KBYTES = (4, 8, 16)
MACRO_MODELS = ("flop", "sram6t", "table")
OBJECTIVES = ("silicon_cluster_area", "scaled_cycles", "silicon_energy")
# Shared memory system, fixed across the grid (as cluster_sweep): 32 KB
# L2, two banked channels.
CLUSTER = ClusterConfig(l2_sets=256, l2_ways=4, mem_channels=2)

# arXiv:2410.08396 (reduced-register RVV): halve the architectural
# vector registers, keep the full-VRF microarchitecture, recover the
# performance in the compiler's register allocator.
BASELINE_REGS = 16
BASELINE_L1_KB = 16
BASELINE_NOTE = (
    "cycles/energy from this sweep's capacity-32 single-core point: "
    "arXiv:2410.08396 reports near-zero performance loss for "
    "compiler-allocated 16-register RVV")

_LAST_EXTRA: dict = {}


def _plan_groups(plan) -> dict:
    """(kernel, l1_geometry, cores) -> plan-group provenance."""
    out = {}
    for gi, g in enumerate(plan):
        for k in g["kernels"]:
            out[(k, g["l1_geometry"], g.get("cores", 1))] = dict(
                plan_group=gi, bucket=g["bucket"], fused=g["fused"])
    return out


def _point_info(res, models) -> dict:
    """(kernel, capacity, l1_kb, cores) -> fold certificate + per-model
    objective values, for provenance stamping and baseline lookup."""
    counters = ["fold_exact", "scaled_cycles"]
    counters += [f"area_{m}" for m in models]
    counters += [f"energy_{m}" for m in models]
    return {(r["kernel"], r["capacity"], r["l1_kb"], r["cores"]): r
            for r in res.to_rows(counters)}


def _external_baseline(res, model, name, info) -> dict:
    """The arXiv:2410.08396 point, priced under ``model``: 16-register
    full-VRF logic + the macro-priced L1, perf from the sweep's largest-
    capacity single-core point (the full-VRF reference)."""
    caps = res.axis("capacity").values
    kbs = sorted({k[2] for k in info})
    l1_kb = BASELINE_L1_KB if BASELINE_L1_KB in kbs else kbs[-1]
    cores = min(res.axis("cores").values)
    geo = api.L1Geometry.from_kbytes(l1_kb)
    m = silicon.get_macro_model(model)
    logic = costmodel.cpu_area(BASELINE_REGS, dispersed=False).total
    l1_au = float(m.area(geo.sets * geo.ways, geo.LINE_BYTES * 8))
    l2 = res.meta["cluster"]
    l2_au = float(m.area(l2["l2_sets"] * l2["l2_ways"], 32 * 8)) \
        if l2["l2_bytes"] else 0.0
    ref = info[(name, max(caps), l1_kb, cores)]
    return dict(
        external=True, source="arXiv:2410.08396",
        label=f"reduced-register RVV ({BASELINE_REGS} arch regs, "
              "full-VRF hardware)",
        kernel=name, macro_model=model, capacity=BASELINE_REGS,
        cores=cores, l1_kb=l1_kb, dispersed=False,
        silicon_cluster_area=logic + l1_au + l2_au,
        scaled_cycles=ref["scaled_cycles"],
        silicon_energy=ref[f"energy_{model}"],
        assumption=BASELINE_NOTE)


def run(names=KERNELS, cores=CORES, caps=CAPS, l1_kbytes=L1_KBYTES,
        models=MACRO_MODELS, cluster=CLUSTER, kernel_params="paper",
        max_events=None, fold=True, session=None) -> list[dict]:
    ses = session or api.default_session()
    sweep = api.Sweep(
        kernels=tuple(names), capacity=tuple(caps),
        l1_geometry=tuple(api.L1Geometry.from_kbytes(kb)
                          for kb in l1_kbytes),
        cores=tuple(cores), cluster=cluster,
        kernel_params=kernel_params, fold=fold, max_events=max_events)
    res, dt = common.timed(ses.run, sweep)
    res = res.derive("scaled_cycles")
    # Re-price the one grid under every macro model: objective columns
    # area_<model> / energy_<model> (flop == the legacy metrics,
    # bit-identically).
    for m in models:
        res = (res.derive("silicon_cluster_area", macro_model=m,
                          out=f"area_{m}")
                  .derive("silicon_energy", macro_model=m,
                          out=f"energy_{m}"))
    info = _point_info(res, models)
    groups = _plan_groups(res.meta["plan"])

    def stamp(row, model):
        """Attach provenance to one front row and surface the objective
        columns under their canonical names."""
        key = (row["kernel"], row["capacity"], row["l1_kb"], row["cores"])
        pt = info[key]
        row = dict(row, macro_model=model,
                   fold_exact=bool(pt["fold_exact"]),
                   **groups[(row["kernel"], row["l1_geometry"],
                             row["cores"])])
        row.pop(f"area_{model}", None)
        row.pop(f"energy_{model}", None)
        row["silicon_cluster_area"] = pt[f"area_{model}"]
        row["scaled_cycles"] = pt["scaled_cycles"]
        row["silicon_energy"] = pt[f"energy_{model}"]
        return row

    fronts = {m: {} for m in models}
    fronts2 = {m: {} for m in models}
    baselines = {m: {} for m in models}
    for m in models:
        for name in sweep.kernels:
            f3 = res.pareto(
                axes=[f"area_{m}", "scaled_cycles", f"energy_{m}"],
                kernel=name)
            f2 = res.pareto(f"area_{m}", "scaled_cycles", kernel=name)
            fronts[m][name] = [stamp(r, m) for r in f3]
            fronts2[m][name] = [stamp(r, m) for r in f2]
            baselines[m][name] = _external_baseline(res, m, name, info)
            fronts[m][name].append(baselines[m][name])

    # Iso-area winner flip: which (cores, capacity, L1) configurations
    # sit on the 2-objective (area, cycles) front under one silicon
    # assumption but not another.
    def config_set(front_rows):
        return {(r["cores"], r["capacity"], r["l1_kb"])
                for r in front_rows}

    winners = {}
    for name in sweep.kernels:
        per = {m: sorted(config_set(fronts2[m][name])) for m in models}
        flop, s6t = set(per["flop"]), set(per.get("sram6t", per["flop"]))
        per["changed"] = sorted(flop ^ s6t)
        winners[name] = {k: [list(c) for c in v] for k, v in per.items()}

    rows = res.to_rows(
        ["cycles", "scaled_cycles", "fold_exact"]
        + [f"area_{m}" for m in models] + [f"energy_{m}" for m in models])
    us_each = dt * 1e6 / max(1, len(rows))
    for r in rows:
        r["name"] = r.pop("kernel")
        r["us_per_call"] = round(us_each, 1)
        r["fold_exact"] = bool(r["fold_exact"])
    plan = res.meta["plan"]
    _LAST_EXTRA.clear()
    _LAST_EXTRA.update(
        objectives=list(OBJECTIVES),
        macro_models=silicon.macro_catalog(),
        cluster=res.meta["cluster"],
        points=res.meta["points"], compiles=res.meta["compiles"],
        dispatches=res.meta["dispatches"],
        plan_groups=len({(g["l1_geometry"], g["bucket"], g["cores"])
                         for g in plan}),
        fold_exact_fraction=float(res.data["fold_exact"].mean()),
        fronts=fronts,
        fronts_2d=fronts2,
        external_baseline=baselines,
        iso_area_winners=winners,
        rows=rows,
    )
    return rows


def main(names=KERNELS, max_events: int | None = None) -> list[dict]:
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "cores", "capacity", "l1_kb",
                       "cycles", "area_flop", "area_sram6t",
                       "energy_flop", "energy_sram6t"])
    fronts = _LAST_EXTRA["fronts"]
    for m, per_kernel in fronts.items():
        print(f"# 3-objective front under macro model '{m}' "
              "(area/cycles/energy):")
        for name, rows_f in per_kernel.items():
            pts = ", ".join(
                ("EXT:" if r.get("external") else "")
                + f"N{r['cores']}/c{r['capacity']}/L1-{r['l1_kb']}KB"
                for r in rows_f)
            print(f"#   {name}: {pts}")
    print("# iso-area winner changes (flop -> sram6t, 2-obj front):")
    for name, per in _LAST_EXTRA["iso_area_winners"].items():
        ch = ", ".join(f"N{c}/c{cap}/L1-{kb}KB"
                       for c, cap, kb in per["changed"]) or "(none)"
        print(f"#   {name}: {ch}")
    return rows


def json_extra() -> dict:
    """DSE payload for ``run.py --json`` (schema >= 7): the macro-model
    catalog, per-model 3-objective fronts with provenance and the
    external arXiv:2410.08396 baseline, 2-objective projections, the
    iso-area winner diff, plan/compile accounting and per-point rows."""
    return dict(_LAST_EXTRA)


if __name__ == "__main__":
    main()
