"""Beyond-paper: the paper's central trade-off as one query — silicon area
vs execution cycles, per kernel, over cVRF capacity x L1 geometry.

Register Dispersion is an area-performance argument: §4.4.1 spends area
savings (3.5x smaller VRF) against Fig 4's cycle overheads.  This study
makes that the object itself: ONE declarative ``Session.run`` over the
``capacity`` and ``l1_geometry`` axes, the ``area_with_l1`` model metric
(CPU+VPU logic plus the L1 SRAM macro, so shrinking the cache is a real
option on the area axis), and ``SweepResult.pareto`` extracting the
maximal (non-dominated) front per kernel.  Design-space studies like
Spatz (arXiv:2309.10137) or reduced-register RVV (arXiv:2410.08396) are
the same query with different axis values.
"""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv

CAPS = (3, 4, 5, 6, 8, 10, 12, 16, 32)
L1_KBYTES = (4, 16)
GEOMETRIES = tuple(api.L1Geometry.from_kbytes(kb) for kb in L1_KBYTES)


def run(max_events=None, fold=True, names=None, session=None,
        caps=CAPS, geometries=GEOMETRIES) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=list(caps),
                           l1_geometry=list(geometries),
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    r = res.derive("area_with_l1").derive("scaled_cycles")
    rows = []
    for name in names:
        front = r.pareto(x="area_with_l1", y="scaled_cycles", kernel=name)
        n_points = len(caps) * len(geometries)
        for f in front:
            rows.append(dict(
                name=name, us_per_call=round(us_each, 1),
                capacity=f["capacity"], l1_kb=f["l1_kb"],
                area_with_l1=round(f["area_with_l1"], 0),
                cycles=int(f["scaled_cycles"]),
                front_size=len(front), grid_points=n_points,
            ))
    return rows


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "capacity", "l1_kb",
                       "area_with_l1", "cycles", "front_size",
                       "grid_points"])
    return rows


if __name__ == "__main__":
    main()
