"""Fig 8: per-application average power — full VRF vs cVRF-8 with Register
Dispersion (activity-based model over simulator counters). Paper: ~10%
average CPU+VPU power saving."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import costmodel, simulator


def run(max_events=None, fold=True, names=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    sweep = simulator.SweepConfig.make([8, 32])
    t00 = time.time()
    grid = common.sweep_grid(names, sweep, fold=fold, max_events=max_events)
    us_each = (time.time() - t00) * 1e6 / len(names)
    rows = []
    savings = []
    for pi, name in enumerate(names):
        out = {k: v[pi] for k, v in grid.items()}
        c8 = {k: float(v[0]) for k, v in out.items()}
        c32 = {k: float(v[1]) for k, v in out.items()}
        p8 = costmodel.application_power(c8, 8, c8["cycles"], dispersed=True)
        p32 = costmodel.application_power(c32, 32, c32["cycles"])
        save = 100 * (1 - p8["total"] / p32["total"])
        savings.append(save)
        rows.append(dict(
            name=name, us_per_call=round(us_each, 1),
            power_full=round(p32["total"], 2),
            power_cvrf8=round(p8["total"], 2),
            saving_pct=round(save, 1),
        ))
    rows.append(dict(name="AVERAGE", us_per_call=0.0,
                     power_full="", power_cvrf8="",
                     saving_pct=round(sum(savings) / len(savings), 1),
                     paper_saving=10.0))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "power_full", "power_cvrf8",
                       "saving_pct", "paper_saving"])
    return rows


if __name__ == "__main__":
    main()
