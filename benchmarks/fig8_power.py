"""Fig 8: per-application average power — full VRF vs cVRF-8 with Register
Dispersion.  Paper: ~10% average CPU+VPU power saving.

The activity-based power model runs vectorized over the whole grid at once
(the ``application_power`` model metric; ``dispersed`` is auto — any
capacity below 32 runs the mechanism), and the saving column is the
baseline-relative ``savings_pct`` query against the full VRF."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api, rvv


def run(max_events=None, fold=True, names=None, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=[8, 32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    r = (res.derive("application_power")
            .derive("savings_pct", of="application_power",
                    baseline=dict(capacity=32), out="power_saving_pct"))
    rows = [dict(
        name=name, us_per_call=round(us_each, 1),
        power_full=round(r.value("application_power", kernel=name,
                                 capacity=32), 2),
        power_cvrf8=round(r.value("application_power", kernel=name,
                                  capacity=8), 2),
        saving_pct=round(r.value("power_saving_pct", kernel=name,
                                 capacity=8), 1),
    ) for name in names]
    avg = float(np.mean(r.array("power_saving_pct", capacity=8)))
    rows.append(dict(name="AVERAGE", us_per_call=0.0,
                     power_full="", power_cvrf8="",
                     saving_pct=round(avg, 1), paper_saving=10.0))
    return rows


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "power_full", "power_cvrf8",
                       "saving_pct", "paper_saving"])
    return rows


if __name__ == "__main__":
    main()
