"""Fig 8: per-application average power — full VRF vs cVRF-8 with Register
Dispersion (activity-based model over simulator counters). Paper: ~10%
average CPU+VPU power saving."""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv
from repro.core import costmodel


def run(max_events=None, fold=True, names=None, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=[8, 32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    rows = []
    savings = []
    for name in names:
        c8 = {k: float(res.value(k, kernel=name, capacity=8))
              for k in res.keys()}
        c32 = {k: float(res.value(k, kernel=name, capacity=32))
               for k in res.keys()}
        p8 = costmodel.application_power(c8, 8, c8["cycles"], dispersed=True)
        p32 = costmodel.application_power(c32, 32, c32["cycles"])
        save = 100 * (1 - p8["total"] / p32["total"])
        savings.append(save)
        rows.append(dict(
            name=name, us_per_call=round(us_each, 1),
            power_full=round(p32["total"], 2),
            power_cvrf8=round(p8["total"], 2),
            saving_pct=round(save, 1),
        ))
    rows.append(dict(name="AVERAGE", us_per_call=0.0,
                     power_full="", power_cvrf8="",
                     saving_pct=round(sum(savings) / len(savings), 1),
                     paper_saving=10.0))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "power_full", "power_cvrf8",
                       "saving_pct", "paper_saving"])
    return rows


if __name__ == "__main__":
    main()
