"""Fig 8: per-application average power — full VRF vs cVRF-8 with Register
Dispersion (activity-based model over simulator counters). Paper: ~10%
average CPU+VPU power saving."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import costmodel, simulator


def run(max_events=common.MAX_EVENTS) -> list[dict]:
    rows = []
    savings = []
    for name in rvv.BENCHMARKS:
        t0 = time.time()
        ev = common.events_for(name)
        sweep = simulator.SweepConfig.make([8, 32])
        out = simulator.simulate_sweep(ev, sweep, max_events=max_events)
        c8 = {k: float(v[0]) for k, v in out.items()}
        c32 = {k: float(v[1]) for k, v in out.items()}
        p8 = costmodel.application_power(c8, 8, c8["cycles"], dispersed=True)
        p32 = costmodel.application_power(c32, 32, c32["cycles"])
        save = 100 * (1 - p8["total"] / p32["total"])
        savings.append(save)
        rows.append(dict(
            name=name, us_per_call=round((time.time() - t0) * 1e6, 1),
            power_full=round(p32["total"], 2),
            power_cvrf8=round(p8["total"], 2),
            saving_pct=round(save, 1),
        ))
    rows.append(dict(name="AVERAGE", us_per_call=0.0,
                     power_full="", power_cvrf8="",
                     saving_pct=round(sum(savings) / len(savings), 1),
                     paper_saving=10.0))
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "power_full", "power_cvrf8",
                        "saving_pct", "paper_saving"])


if __name__ == "__main__":
    main()
