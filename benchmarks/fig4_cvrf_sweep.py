"""Fig 4: (a) performance of cVRF sizes 3..16 normalised to the full VRF and
(b) cVRF hit rates, for every benchmark application (FIFO, as the paper)."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import simulator

CAPS = list(range(3, 17))


def run(names=None, max_events=common.MAX_EVENTS) -> list[dict]:
    rows = []
    for name in names or rvv.BENCHMARKS:
        t0 = time.time()
        ev = common.events_for(name)
        sweep = simulator.SweepConfig.make(CAPS + [32])
        out = simulator.simulate_sweep(ev, sweep, max_events=max_events)
        full = float(out["cycles"][-1])
        for i, cap in enumerate(CAPS):
            rows.append(dict(
                name=name, us_per_call=round((time.time() - t0) * 1e6, 1),
                capacity=cap,
                norm_perf=round(full / float(out["cycles"][i]), 4),
                hit_rate=round(float(out["hit_rate"][i]), 4),
                spills=int(out["spills"][i]), fills=int(out["fills"][i]),
            ))
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "capacity", "norm_perf",
                        "hit_rate", "spills", "fills"])


if __name__ == "__main__":
    main()
