"""Fig 4: (a) performance of cVRF sizes 3..16 normalised to the full VRF and
(b) cVRF hit rates, for every benchmark application (FIFO, as the paper).

One declarative sweep: all applications x all capacities through
``repro.api`` — the Session plans one fused engine call per program-shape
bucket (folded traces, exact for steady-state kernels).  The normalised
performance column is the ``speedup`` metric against the full-VRF
baseline.
"""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv

CAPS = list(range(3, 17))


def run(names=None, max_events=None, fold=True, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=CAPS + [32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    r = res.derive("speedup", baseline=dict(capacity=32))
    rows = []
    for name in names:
        for cap in CAPS:
            pt = dict(kernel=name, capacity=cap)
            rows.append(dict(
                name=name, us_per_call=round(us_each, 1), capacity=cap,
                norm_perf=round(r.value("speedup", **pt), 4),
                hit_rate=round(r.value("hit_rate", **pt), 4),
                spills=r.value("spills", **pt),
                fills=r.value("fills", **pt),
                fold_exact=r.value("fold_exact", **pt),
            ))
    return rows


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "capacity", "norm_perf",
                       "hit_rate", "spills", "fills", "fold_exact"])
    return rows


if __name__ == "__main__":
    main()
