"""Fig 4: (a) performance of cVRF sizes 3..16 normalised to the full VRF and
(b) cVRF hit rates, for every benchmark application (FIFO, as the paper).

One sweep-grid call: all applications x all capacities in one engine
dispatch per shape bucket (folded traces, exact for steady-state kernels).
"""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import simulator

CAPS = list(range(3, 17))


def run(names=None, max_events=None, fold=True) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    sweep = simulator.SweepConfig.make(CAPS + [32])
    t0 = time.time()
    out = common.sweep_grid(names, sweep, fold=fold, max_events=max_events)
    us_each = (time.time() - t0) * 1e6 / len(names)
    rows = []
    for pi, name in enumerate(names):
        full = float(out["cycles"][pi, -1])
        exact = out.get("fold_exact")
        for ci, cap in enumerate(CAPS):
            rows.append(dict(
                name=name, us_per_call=round(us_each, 1), capacity=cap,
                norm_perf=round(full / float(out["cycles"][pi, ci]), 4),
                hit_rate=round(float(out["hit_rate"][pi, ci]), 4),
                spills=int(out["spills"][pi, ci]),
                fills=int(out["fills"][pi, ci]),
                fold_exact=bool(exact[pi, ci]) if exact is not None else True,
            ))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "capacity", "norm_perf",
                       "hit_rate", "spills", "fills", "fold_exact"])
    return rows


if __name__ == "__main__":
    main()
