"""Benchmark harness: one function per paper table/figure (+ beyond-paper
studies).  Prints ``name,us_per_call,derived...`` CSV blocks per benchmark.

  python -m benchmarks.run             # everything
  python -m benchmarks.run table3 fig4 # subset
"""

from __future__ import annotations

import sys
import time

SUITES = ("table3", "fig4", "fig5", "fig6", "fig2", "fig8",
          "policy_headroom", "vmem_dispersion", "kv_dispersion",
          "ablation_sensitivity")


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or list(SUITES)
    t00 = time.time()
    for suite in args:
        mod = {
            "table3": "benchmarks.table3_speedup",
            "fig4": "benchmarks.fig4_cvrf_sweep",
            "fig5": "benchmarks.fig5_min_regs",
            "fig6": "benchmarks.fig6_equal_area",
            "fig2": "benchmarks.fig2_area_model",
            "fig8": "benchmarks.fig8_power",
            "policy_headroom": "benchmarks.policy_headroom",
            "vmem_dispersion": "benchmarks.vmem_dispersion",
            "kv_dispersion": "benchmarks.kv_dispersion",
            "ablation_sensitivity": "benchmarks.ablation_sensitivity",
        }[suite]
        print(f"\n## {suite} ({mod})", flush=True)
        t0 = time.time()
        __import__(mod, fromlist=["main"]).main()
        print(f"## {suite} done in {time.time() - t0:.1f}s", flush=True)
    print(f"\nALL BENCHMARKS DONE in {time.time() - t00:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
