"""Benchmark harness: one function per paper table/figure (+ beyond-paper
studies).  Prints ``name,us_per_call,derived...`` CSV blocks per benchmark.

  python -m benchmarks.run                       # everything
  python -m benchmarks.run table3 fig4           # subset
  python -m benchmarks.run --json BENCH_core.json fig4 table3
  python -m benchmarks.run --kernels dropout,gemv --json BENCH_smoke.json

``--kernels a,b`` restricts every suite whose ``main()`` takes a kernel
list (table3/fig4/fig5/fig6/fig8/pareto) to that subset; fixed-roster
studies (fig2, policy_headroom, ablation_sensitivity, ...) run their own
set and say so.  ``make bench-smoke`` uses it to guard the JSON schema
cheaply.  ``--max-events N`` forwards the legacy truncation budget the
same way.

``--json PATH`` writes a versioned report (``schema: 6``): per-suite
wall-clock, XLA compile AND dispatch counts (the fused engine compiles once
per (program-shape bucket, L1 geometry) — machine-latency grids are traced,
so they add rows, not compiles), the sweep-axis metadata of every
``repro.api`` sweep the suite ran *including the metrics it derived*
(name, kind, baseline, params), the full ``repro.metrics`` registry
catalog, per-kernel cycle counts (the perf trajectory record for this
machine), and — schema 4 — any per-suite ``json_extra()`` payload (the
serving SLO suite exports its footprint-vs-latency Pareto fronts there;
the roofline suite its per-point measured/model rows and equal-VMEM
winners).  Suites exposing ``perf_stats()`` add their own Pallas
compile/dispatch counts to the suite record.  Schema 5 adds the
``network_sweep`` suite: whole registry models lowered through
``repro.bridge``, with per-model footprint/cycles/energy rows and the
lowered-network summaries (kernels, units, instances) in its ``extra``
payload, plus ``networks`` on any sweep meta that used the ``network``
axis.  Schema 6 adds the ``cluster_sweep`` suite (``repro.cluster``:
N lockstep dispersion cores behind a shared L2 + banked memory channels,
one compile per (bucket, geometry, cores) plan group) with per-point
cluster counters and iso-SRAM-budget / iso-area Pareto fronts in its
``extra`` payload.  Schema 7 adds the ``dse`` suite
(:mod:`repro.silicon`: pluggable SRAM macro models pricing one capacity
x L1 x cores grid per silicon backend, 3-objective area/cycles/energy
fronts with per-point provenance, the arXiv:2410.08396 reduced-register
RVV design as a labeled external baseline, and the flop -> sram6t
iso-area winner diff in its ``extra`` payload) plus the top-level
``macro_models`` catalog naming the silicon every report's areas assume.
"""

from __future__ import annotations

import inspect
import json
import sys
import time

from repro import api, metrics, silicon
from repro.core import simulator

SCHEMA_VERSION = 7

_MODULES = {
    "table3": "benchmarks.table3_speedup",
    "fig4": "benchmarks.fig4_cvrf_sweep",
    "fig5": "benchmarks.fig5_min_regs",
    "fig6": "benchmarks.fig6_equal_area",
    "fig2": "benchmarks.fig2_area_model",
    "fig8": "benchmarks.fig8_power",
    "pareto": "benchmarks.pareto_frontier",
    "policy_headroom": "benchmarks.policy_headroom",
    "vmem_dispersion": "benchmarks.vmem_dispersion",
    "kv_dispersion": "benchmarks.kv_dispersion",
    "serving_slo": "benchmarks.serving_slo",
    "ablation_sensitivity": "benchmarks.ablation_sensitivity",
    "roofline": "benchmarks.roofline",
    "network_sweep": "benchmarks.network_sweep",
    "cluster_sweep": "benchmarks.cluster_sweep",
    "dse": "benchmarks.dse",
}

SUITES = tuple(_MODULES)

_CYCLE_KEYS = ("vec_cycles", "scalar_cycles", "fifo_cycles",
               "fifo_no_fetch_cycles", "cycles")


def _sweep_meta(history_slice: list[dict]) -> list[dict]:
    """Axis + derived-metric metadata for the suite's ``Session.run``
    calls (JSON-safe)."""
    return [dict(axes=h["axes"], points=h["points"],
                 compiles=h["compiles"], dispatches=h["dispatches"],
                 fold=h["fold"], kernel_params=h["kernel_params"],
                 derived=list(h.get("derived", ())),
                 **({"networks": h["networks"]} if "networks" in h else {}))
            for h in history_slice]


def _call_main(mod, kernels, max_events):
    """Invoke a suite's main(), forwarding only the kwargs it accepts."""
    params = inspect.signature(mod.main).parameters
    kw = {}
    if kernels:
        if "names" in params:
            kw["names"] = list(kernels)
        else:
            print("(fixed-roster suite: --kernels ignored)", flush=True)
    if max_events and "max_events" in params:
        kw["max_events"] = max_events
    return mod.main(**kw) or []


def _pop_flag(args: list, flag: str):
    if flag not in args:
        return None
    i = args.index(flag)
    if i + 1 >= len(args):
        raise SystemExit(f"error: {flag} requires a value")
    value = args[i + 1]
    del args[i:i + 2]
    return value


def main(argv=None) -> int:
    args = list(argv if argv is not None else sys.argv[1:])
    try:
        json_path = _pop_flag(args, "--json")
        kernels = _pop_flag(args, "--kernels")
        max_events = _pop_flag(args, "--max-events")
    except SystemExit as e:
        print(e, file=sys.stderr)
        return 2
    kernels = [k for k in kernels.split(",") if k] if kernels else None
    if max_events is not None:
        try:
            max_events = int(max_events)
            if max_events <= 0:
                raise ValueError
        except ValueError:
            print(f"error: --max-events needs a positive integer, got "
                  f"{max_events!r}", file=sys.stderr)
            return 2
    suites = args or list(SUITES)
    unknown = [s for s in suites if s not in _MODULES]
    if unknown:
        print(f"error: unknown suite(s) {', '.join(unknown)}; "
              f"choose from: {', '.join(SUITES)}", file=sys.stderr)
        return 2
    session = api.default_session()
    report = {"schema": SCHEMA_VERSION, "suites": {}, "kernels": {},
              "metrics": metrics.catalog(),
              "macro_models": silicon.macro_catalog()}
    t00 = time.time()
    for suite in suites:
        mod = __import__(_MODULES[suite], fromlist=["main"])
        print(f"\n## {suite} ({_MODULES[suite]})", flush=True)
        t0 = time.time()
        c0 = simulator.compile_count()
        d0 = simulator.dispatch_count()
        h0 = len(session.history)
        ps0 = mod.perf_stats() if hasattr(mod, "perf_stats") else {}
        rows = _call_main(mod, kernels, max_events)
        dt = time.time() - t0
        print(f"## {suite} done in {dt:.1f}s", flush=True)
        report["suites"][suite] = {
            "wall_s": round(dt, 2),
            "rows": len(rows),
            "compiles": simulator.compile_count() - c0,
            "dispatches": simulator.dispatch_count() - d0,
            "sweeps": _sweep_meta(session.history[h0:]),
        }
        # Suites that drive Pallas kernels directly (the roofline) count
        # their own compiles/dispatches — the simulator probes never see
        # those executions.
        if hasattr(mod, "perf_stats"):
            ps = mod.perf_stats()
            for key in ("compiles", "dispatches"):
                report["suites"][suite][key] += \
                    ps.get(key, 0) - ps0.get(key, 0)
        # schema 4: suites may export a JSON-safe payload of their own
        # (e.g. serving_slo's footprint-vs-latency Pareto fronts)
        if hasattr(mod, "json_extra"):
            report["suites"][suite]["extra"] = mod.json_extra()
        for r in rows:
            cyc = {k: r[k] for k in _CYCLE_KEYS if k in r}
            if cyc and isinstance(r.get("name"), str):
                kern = report["kernels"].setdefault(r["name"], {})
                # Every grid field the row carries keys the record, so
                # e.g. pareto rows at the same capacity but different L1
                # geometries never overwrite each other.
                suffix = "".join(
                    f"_{tag}{r[f]}" for tag, f in
                    (("cap", "capacity"), ("l1", "l1_kb")) if f in r)
                for k, v in cyc.items():
                    kern[f"{suite}{suffix}.{k}"] = v
    total = time.time() - t00
    print(f"\nALL BENCHMARKS DONE in {total:.1f}s")
    if json_path:
        report["total_wall_s"] = round(total, 2)
        report["total_compiles"] = simulator.compile_count()
        report["total_dispatches"] = simulator.dispatch_count()
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {json_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
