"""Benchmark harness: one function per paper table/figure (+ beyond-paper
studies).  Prints ``name,us_per_call,derived...`` CSV blocks per benchmark.

  python -m benchmarks.run                       # everything
  python -m benchmarks.run table3 fig4           # subset
  python -m benchmarks.run --json BENCH_core.json fig4 table3

``--json PATH`` writes a versioned report (``schema: 2``): per-suite
wall-clock, XLA compile AND dispatch counts (the fused engine compiles once
per (program-shape bucket, L1 geometry) — machine-latency grids are traced,
so they add rows, not compiles), the sweep-axis metadata of every
``repro.api`` sweep the suite ran, and per-kernel cycle counts (the perf
trajectory record for this machine).
"""

from __future__ import annotations

import json
import sys
import time

from repro import api
from repro.core import simulator

SCHEMA_VERSION = 2

_MODULES = {
    "table3": "benchmarks.table3_speedup",
    "fig4": "benchmarks.fig4_cvrf_sweep",
    "fig5": "benchmarks.fig5_min_regs",
    "fig6": "benchmarks.fig6_equal_area",
    "fig2": "benchmarks.fig2_area_model",
    "fig8": "benchmarks.fig8_power",
    "policy_headroom": "benchmarks.policy_headroom",
    "vmem_dispersion": "benchmarks.vmem_dispersion",
    "kv_dispersion": "benchmarks.kv_dispersion",
    "ablation_sensitivity": "benchmarks.ablation_sensitivity",
}

SUITES = tuple(_MODULES)

_CYCLE_KEYS = ("vec_cycles", "scalar_cycles", "fifo_cycles",
               "fifo_no_fetch_cycles", "cycles")


def _sweep_meta(history_slice: list[dict]) -> list[dict]:
    """Axis metadata for the suite's ``Session.run`` calls (JSON-safe)."""
    return [dict(axes=h["axes"], points=h["points"],
                 compiles=h["compiles"], dispatches=h["dispatches"],
                 fold=h["fold"], kernel_params=h["kernel_params"])
            for h in history_slice]


def main(argv=None) -> int:
    args = list(argv if argv is not None else sys.argv[1:])
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            print("error: --json requires a file path", file=sys.stderr)
            return 2
        json_path = args[i + 1]
        del args[i:i + 2]
    suites = args or list(SUITES)
    unknown = [s for s in suites if s not in _MODULES]
    if unknown:
        print(f"error: unknown suite(s) {', '.join(unknown)}; "
              f"choose from: {', '.join(SUITES)}", file=sys.stderr)
        return 2
    session = api.default_session()
    report = {"schema": SCHEMA_VERSION, "suites": {}, "kernels": {}}
    t00 = time.time()
    for suite in suites:
        mod = _MODULES[suite]
        print(f"\n## {suite} ({mod})", flush=True)
        t0 = time.time()
        c0 = simulator.compile_count()
        d0 = simulator.dispatch_count()
        h0 = len(session.history)
        rows = __import__(mod, fromlist=["main"]).main() or []
        dt = time.time() - t0
        print(f"## {suite} done in {dt:.1f}s", flush=True)
        report["suites"][suite] = {
            "wall_s": round(dt, 2),
            "rows": len(rows),
            "compiles": simulator.compile_count() - c0,
            "dispatches": simulator.dispatch_count() - d0,
            "sweeps": _sweep_meta(session.history[h0:]),
        }
        for r in rows:
            cyc = {k: r[k] for k in _CYCLE_KEYS if k in r}
            if cyc and isinstance(r.get("name"), str):
                kern = report["kernels"].setdefault(r["name"], {})
                suffix = f"_cap{r['capacity']}" if "capacity" in r else ""
                for k, v in cyc.items():
                    kern[f"{suite}{suffix}.{k}"] = v
    total = time.time() - t00
    print(f"\nALL BENCHMARKS DONE in {total:.1f}s")
    if json_path:
        report["total_wall_s"] = round(total, 2)
        report["total_compiles"] = simulator.compile_count()
        report["total_dispatches"] = simulator.dispatch_count()
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {json_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
