"""Roofline table over the dry-run sweep (results/dryrun/*.json).

Per (arch x shape) on the single-pod mesh: the three roofline terms in
seconds, the dominant bottleneck, MODEL_FLOPS (6ND / 6N_active*D + attention
term), the useful-FLOP ratio, and the roofline fraction
(t_compute / max(all terms)).  Reachable from the front door as
``python -m benchmarks.run roofline``; it replaces the old standalone
``benchmarks.report`` markdown generator — ``run(mesh="multi")`` reads
the multi-pod cells and the ``status``/``compile_s``/``mem_gb_per_dev``
columns carry that table's dry-run facts.  With no ``results/dryrun``
sweep on disk it emits an empty table rather than failing."""

from __future__ import annotations

import glob
import json
import os

from benchmarks import common
from repro.configs import ARCHS, SHAPES, get
from repro.launch import analytic

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}.json"))):
        try:
            cells.extend(json.load(open(f)))
        except Exception:
            pass
    return cells


def run(mesh: str = "single") -> list[dict]:
    rows = []
    for cell in load_cells(mesh):
        name = f"{cell['arch']}/{cell['shape']}"
        if cell["status"] == "skip":
            rows.append(dict(name=name, us_per_call=0.0, status="skip"))
            continue
        if cell["status"] != "ok":
            rows.append(dict(name=name, us_per_call=0.0, status="error"))
            continue
        cfg = get(cell["arch"])
        shape = SHAPES[cell["shape"]]
        t = analytic.roofline_terms(cell, cfg, shape)
        rows.append(dict(
            name=name, us_per_call=0.0, status="ok",
            t_compute_ms=round(t["t_compute"] * 1e3, 3),
            t_memory_ms=round(t["t_memory"] * 1e3, 3),
            t_mem_ub_ms=round(t["t_memory_opbytes_ub"] * 1e3, 3),
            t_collective_ms=round(t["t_collective"] * 1e3, 3),
            bottleneck=t["bottleneck"],
            roofline_frac=round(t["roofline_fraction"], 3),
            useful_flop_ratio=round(t["useful_flop_ratio"], 3),
            mem_gb_per_dev=round(cell.get("bytes_per_device", 0) / 1e9, 2),
            fits_16g=cell.get("fits_16g", ""),
            compile_s=round(cell.get("compile_s", 0), 1),
        ))
    return rows


def main():
    rows = []
    for mesh in ("single",):
        print(f"# mesh={mesh}")
        rows = run(mesh)
        common.emit(rows, [
            "name", "us_per_call", "status", "t_compute_ms", "t_memory_ms",
            "t_mem_ub_ms", "t_collective_ms", "bottleneck", "roofline_frac",
            "useful_flop_ratio", "mem_gb_per_dev", "fits_16g", "compile_s"])
    return rows


if __name__ == "__main__":
    main()
