"""Measured roofline over the Pallas kernels (+ the legacy dry-run table).

The suite times the three dispersed-accumulator schedules —
``matmul_grouped`` (working set W >= 1), ``matmul_dispersed`` (the W=0
spill/fill extreme) and ``flash_attention`` — in interpret mode on CPU and
natively on TPU/GPU (``ops._auto_interpret`` picks), and cross-checks every
point against the closed-form ``hbm_traffic_model`` bytes: the instrumented
traffic count (:mod:`repro.kernels.traffic`, walking the schedule's actual
BlockSpec index maps) must agree with the model, and each row carries both
arithmetic-intensity columns plus a per-row ``model_agree`` flag.

The accumulator working set ``W`` and the input precision (f32 / bf16 /
int8, SPEED's multi-precision angle — int8 streams operands at one byte
per element while the accumulators stay f32) are first-class labeled
axes: rows are
assembled through :meth:`repro.api.SweepResult.from_table`, so the
``derive`` / ``normalize`` / ``pareto`` machinery applies — the suite
derives ``arithmetic_intensity`` / ``achieved_gflops`` from the metric
registry, normalizes time against the W=0 extreme, and reports the
VMEM-footprint-vs-time Pareto front per shape.  An equal-VMEM study
mirrors fig6: at a fixed VMEM accumulator budget, which (W, block_m,
block_k) point wins.

``run(mesh=...)`` keeps the legacy dry-run table (``results/dryrun/*.json``
from the launch sweep) but now *warns* when the sweep is absent instead of
silently emitting nothing; ``load_cells`` reports unreadable cell files.
``json_extra()`` exports the per-point measured/model rows for
``run.py --json`` (schema >= 4) and ``perf_stats()`` its Pallas
compile/dispatch counts, so ``BENCH_core.json`` can never again record a
silent ``{"rows": 0}``.
"""

from __future__ import annotations

import glob
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import api
from repro.configs import SHAPES, get
from repro.kernels import dispersed_gemm, flash_attention, ops, traffic
from repro.launch import analytic

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

# (m, k, n) GEMM cases and (b, h, s, d) attention cases, sized so the
# interpret-mode sweep stays CPU-affordable; on a real TPU/GPU backend the
# same axes time the compiled kernels.
GEMM_CASES = {"gemm_256x512x256": (256, 512, 256),
              "gemm_512x512x256": (512, 512, 256)}
FLASH_CASES = {"attn_b1h2_s256_d64": (1, 2, 256, 64)}
W_AXIS = (0, 1, 2, 4)                  # 0 = the dispersed (spill/fill) extreme
PRECISIONS = ("f32", "bf16", "int8")
BLOCK_M, BLOCK_K = 64, 128
FLASH_BLOCK = 64

SMOKE_GEMM_CASES = {"gemm_128x256x128": (128, 256, 128)}
SMOKE_FLASH_CASES = {"attn_b1h1_s128_d64": (1, 1, 128, 64)}
SMOKE_W_AXIS = (0, 1, 2)

# Counted-vs-model agreement: both sides are exact byte counts, so the
# tolerance only absorbs float round-off in the ratio.
AGREE_RTOL = 0.01

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_BYTES = {"f32": 4, "bf16": 2, "int8": 1}

_LAST_EXTRA: dict = {}
_STATS = {"compiles": 0, "dispatches": 0}
_SEEN_SIGNATURES: set = set()


def _measure(fn, signature, repeats: int) -> float:
    """Median wall-clock us per call (one warm-up, ``repeats`` timed).
    Tracks Pallas compiles (first sighting of a jit signature) and
    dispatches for ``perf_stats()``."""
    if signature not in _SEEN_SIGNATURES:
        _SEEN_SIGNATURES.add(signature)
        _STATS["compiles"] += 1
    fn().block_until_ready()                      # warm-up / compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        times.append(time.perf_counter() - t0)
    _STATS["dispatches"] += repeats + 1
    times.sort()
    return times[len(times) // 2] * 1e6


def _gemm_point(case, m, k, n, w, prec, *, block_m, block_k, interpret,
                repeats) -> dict:
    dtype, bpe = _DTYPES[prec], _BYTES[prec]
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    a, b = a.astype(dtype), b.astype(dtype)
    model = dispersed_gemm.hbm_traffic_model(
        m, n, k, block_m=block_m, block_k=block_k,
        working_set=max(w, 1), bytes_per_el=bpe)
    if w == 0:
        fn = lambda: dispersed_gemm.matmul_dispersed(
            a, b, block_m=block_m, block_k=block_k, interpret=interpret)
        schedule = dispersed_gemm.dispersed_schedule(
            m, n, k, block_m=block_m, block_k=block_k, bytes_per_el=bpe)
        model_bytes, vmem_acc = model["dispersed"], 0
        name = f"{case}_dispersed_{prec}"
    else:
        fn = lambda: dispersed_gemm.matmul_grouped(
            a, b, block_m=block_m, block_k=block_k, working_set=w,
            interpret=interpret)
        schedule = dispersed_gemm.grouped_schedule(
            m, n, k, block_m=block_m, block_k=block_k, working_set=w,
            bytes_per_el=bpe)
        model_bytes, vmem_acc = model["grouped"], model["vmem_acc_bytes"]
        name = f"{case}_W{w}_{prec}"
    counted = traffic.count(schedule)["total"]
    us = _measure(fn, ("gemm", m, k, n, w, block_m, block_k, prec),
                  repeats)
    return dict(
        name=name, case=case, kernel="gemm", working_set=w, precision=prec,
        block_m=block_m, block_k=block_k, us_per_call=round(us, 1),
        flops=2 * m * n * k, counted_bytes=counted, model_bytes=model_bytes,
        model_agree=abs(counted - model_bytes) <= AGREE_RTOL * model_bytes,
        vmem_acc_bytes=vmem_acc)


def _flash_point(case, b, h, s, d, prec, *, interpret, repeats) -> dict:
    dtype, bpe = _DTYPES[prec], _BYTES[prec]
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32).astype(dtype)
               for kk in keys)
    model = flash_attention.hbm_traffic_model(
        b, h, s, s, d, block_q=FLASH_BLOCK, block_k=FLASH_BLOCK,
        bytes_per_el=bpe)
    counted = traffic.count(flash_attention.flash_schedule(
        b, h, s, s, d, block_q=FLASH_BLOCK, block_k=FLASH_BLOCK,
        bytes_per_el=bpe))["total"]
    fn = lambda: flash_attention.flash_attention(
        q, k, v, block_q=FLASH_BLOCK, block_k=FLASH_BLOCK,
        interpret=interpret)
    us = _measure(fn, ("flash", b, h, s, d, prec), repeats)
    return dict(
        name=f"{case}_{prec}", case=case, kernel="flash",
        working_set=1, precision=prec, block_m=FLASH_BLOCK,
        block_k=FLASH_BLOCK, us_per_call=round(us, 1),
        flops=4 * b * h * s * s * d, counted_bytes=counted,
        model_bytes=model["flash"],
        model_agree=abs(counted - model["flash"])
        <= AGREE_RTOL * model["flash"],
        vmem_acc_bytes=model["vmem_acc_bytes"])


def _grid_fields(rows):
    keep = ("us_per_call", "flops", "counted_bytes", "model_bytes",
            "model_agree", "vmem_acc_bytes")
    return [{k: r[k] for k in
             ("case", "working_set", "precision") + keep} for r in rows]


def equal_vmem_points(m: int) -> list[tuple[int, int, int]]:
    """fig6 mirrored at VMEM granularity: (W, block_m, block_k) points
    with the same accumulator footprint W*block_m*n*4 — more, smaller
    registers vs fewer, taller ones at equal area."""
    pts = [(4, 64, 128), (2, 128, 128), (1, 256, 64)]
    return [(w, bm, bk) for (w, bm, bk) in pts
            if m % bm == 0 and (m // bm) % w == 0]


def run_measured(smoke: bool = False, repeats: int = 3):
    """Execute the measured suite.

    Returns ``(gemm_result, flash_result, rows)``: two labeled
    :class:`repro.api.SweepResult` grids (axes ``case`` x ``working_set``
    x ``precision`` and ``case`` x ``precision``) with the registry
    metrics derived, plus the flat row list (including the equal-VMEM
    study rows, which vary ``block_m``/``block_k`` off the main grid).
    """
    interpret = ops._auto_interpret()
    gemm_cases = SMOKE_GEMM_CASES if smoke else GEMM_CASES
    flash_cases = SMOKE_FLASH_CASES if smoke else FLASH_CASES
    w_axis = SMOKE_W_AXIS if smoke else W_AXIS
    precisions = ("f32",) if smoke else PRECISIONS
    repeats = 1 if smoke else repeats

    rows = []
    for case, (m, k, n) in gemm_cases.items():
        for w in w_axis:
            for prec in precisions:
                rows.append(_gemm_point(
                    case, m, k, n, w, prec, block_m=BLOCK_M,
                    block_k=BLOCK_K, interpret=interpret, repeats=repeats))
    gemm_result = api.SweepResult.from_table(
        dict(case=tuple(gemm_cases), working_set=w_axis,
             precision=precisions),
        _grid_fields(rows),
        values=["us_per_call", "flops", "counted_bytes", "model_bytes",
                "model_agree", "vmem_acc_bytes"])
    gemm_result = (gemm_result.derive("arithmetic_intensity")
                   .derive("model_arithmetic_intensity")
                   .derive("achieved_gflops"))
    # time normalized to the W=0 spill/fill extreme: > 1 means the compact
    # working set pays off (Fig 4's economics, measured)
    rel = gemm_result.normalize("us_per_call",
                                baseline=dict(working_set=0))
    for r in rows:
        r["speedup_vs_dispersed"] = round(
            1.0 / rel.value("us_per_call", case=r["case"],
                            working_set=r["working_set"],
                            precision=r["precision"]), 3)
        r["ai_measured"] = round(gemm_result.value(
            "arithmetic_intensity", case=r["case"],
            working_set=r["working_set"], precision=r["precision"]), 2)
        r["ai_model"] = round(gemm_result.value(
            "model_arithmetic_intensity", case=r["case"],
            working_set=r["working_set"], precision=r["precision"]), 2)

    flash_rows = []
    for case, (b, h, s, d) in flash_cases.items():
        for prec in precisions:
            flash_rows.append(_flash_point(
                case, b, h, s, d, prec, interpret=interpret,
                repeats=repeats))
    flash_result = api.SweepResult.from_table(
        dict(case=tuple(flash_cases), precision=precisions),
        [{k: r[k] for k in ("case", "precision", "us_per_call", "flops",
                            "counted_bytes", "model_bytes", "model_agree",
                            "vmem_acc_bytes")} for r in flash_rows],
        values=["us_per_call", "flops", "counted_bytes", "model_bytes",
                "model_agree", "vmem_acc_bytes"])
    flash_result = (flash_result.derive("arithmetic_intensity")
                    .derive("model_arithmetic_intensity")
                    .derive("achieved_gflops"))
    for r in flash_rows:
        r["speedup_vs_dispersed"] = ""
        r["ai_measured"] = round(flash_result.value(
            "arithmetic_intensity", case=r["case"],
            precision=r["precision"]), 2)
        r["ai_model"] = round(flash_result.value(
            "model_arithmetic_intensity", case=r["case"],
            precision=r["precision"]), 2)
    rows += flash_rows

    # equal-VMEM study (fig6 at VMEM granularity): fixed accumulator
    # budget, which (W, block_m, block_k) schedule wins?
    equal_vmem = []
    if not smoke:
        for case, (m, k, n) in gemm_cases.items():
            pts = []
            for w, bm, bk in equal_vmem_points(m):
                p = _gemm_point(case, m, k, n, w, "f32", block_m=bm,
                                block_k=bk, interpret=interpret,
                                repeats=repeats)
                p["name"] = f"eqvmem_{case}_W{w}_bm{bm}_bk{bk}"
                p["speedup_vs_dispersed"] = ""
                p["ai_measured"] = round(
                    p["flops"] / p["counted_bytes"], 2)
                p["ai_model"] = round(p["flops"] / p["model_bytes"], 2)
                pts.append(p)
            if not pts:
                continue
            budgets = {p["vmem_acc_bytes"] for p in pts}
            measured_win = min(pts, key=lambda p: p["us_per_call"])
            # Equal budget => equal groups => the closed form often
            # predicts a byte tie; measured timing breaks it.
            best_bytes = min(p["model_bytes"] for p in pts)
            model_wins = [p["name"] for p in pts
                          if p["model_bytes"] == best_bytes]
            equal_vmem.append(dict(
                case=case, vmem_budget_bytes=sorted(budgets),
                points=[dict(working_set=p["working_set"],
                             block_m=p["block_m"], block_k=p["block_k"],
                             us_per_call=p["us_per_call"],
                             model_bytes=p["model_bytes"]) for p in pts],
                measured_winner=measured_win["name"],
                model_winner=(model_wins[0] if len(model_wins) == 1
                              else "tie(" + ", ".join(model_wins) + ")")))
            rows += pts

    global _LAST_EXTRA
    _LAST_EXTRA = dict(
        rows=[{k: (v if not isinstance(v, bool) else bool(v))
               for k, v in r.items()} for r in rows],
        equal_vmem=equal_vmem,
        pareto={case: gemm_result.pareto(
            "vmem_acc_bytes", "us_per_call", case=case, precision=prec)
            for case in gemm_cases for prec in precisions[:1]},
        axes=dict(case=list(gemm_cases) + list(flash_cases),
                  working_set=list(w_axis), precision=list(precisions)),
        interpret=interpret,
    )
    return gemm_result, flash_result, rows


# ---------------------------------------------------------------------------
# Legacy dry-run table (results/dryrun/*.json from the launch sweep).
# ---------------------------------------------------------------------------


def load_cells(mesh: str = "single") -> list[dict]:
    """Load the dry-run sweep cells; unreadable/corrupt files are counted
    and reported (a warning naming each file) instead of silently
    dropped."""
    cells, skipped = [], []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}.json"))):
        try:
            with open(f) as fh:
                cells.extend(json.load(fh))
        except Exception as e:
            skipped.append(f"{os.path.basename(f)} ({e})")
    if skipped:
        warnings.warn(
            f"load_cells: skipped {len(skipped)} unreadable dry-run cell "
            f"file(s): {'; '.join(skipped)}", stacklevel=2)
    return cells


def run(mesh: str = "single") -> list[dict]:
    """The dry-run-cells roofline table (unchanged schema).  Warns — loudly
    but non-fatally — when the ``results/dryrun`` sweep has never been
    generated, instead of silently emitting an empty table."""
    if not os.path.isdir(RESULTS):
        warnings.warn(
            f"no dry-run sweep at {os.path.normpath(RESULTS)}; the "
            f"dry-run roofline table is empty (the *measured* Pallas "
            f"roofline via main()/run_measured() does not need it)",
            stacklevel=2)
        return []
    rows = []
    for cell in load_cells(mesh):
        name = f"{cell['arch']}/{cell['shape']}"
        if cell["status"] == "skip":
            rows.append(dict(name=name, us_per_call=0.0, status="skip"))
            continue
        if cell["status"] != "ok":
            rows.append(dict(name=name, us_per_call=0.0, status="error"))
            continue
        cfg = get(cell["arch"])
        shape = SHAPES[cell["shape"]]
        t = analytic.roofline_terms(cell, cfg, shape)
        rows.append(dict(
            name=name, us_per_call=0.0, status="ok",
            t_compute_ms=round(t["t_compute"] * 1e3, 3),
            t_memory_ms=round(t["t_memory"] * 1e3, 3),
            t_mem_ub_ms=round(t["t_memory_opbytes_ub"] * 1e3, 3),
            t_collective_ms=round(t["t_collective"] * 1e3, 3),
            bottleneck=t["bottleneck"],
            roofline_frac=round(t["roofline_fraction"], 3),
            useful_flop_ratio=round(t["useful_flop_ratio"], 3),
            mem_gb_per_dev=round(cell.get("bytes_per_device", 0) / 1e9, 2),
            fits_16g=cell.get("fits_16g", ""),
            compile_s=round(cell.get("compile_s", 0), 1),
        ))
    return rows


# ---------------------------------------------------------------------------
# Front door.
# ---------------------------------------------------------------------------

_HEADER = ["name", "us_per_call", "working_set", "precision",
           "speedup_vs_dispersed", "ai_measured", "ai_model", "model_agree",
           "counted_bytes", "model_bytes", "vmem_acc_bytes"]


def main(max_events: int | None = None) -> list[dict]:
    smoke = max_events is not None and max_events <= 5000
    _, _, rows = run_measured(smoke=smoke)
    common.emit(rows, _HEADER)
    for study in _LAST_EXTRA.get("equal_vmem", ()):
        print(f"# equal-VMEM {study['case']}: measured winner "
              f"{study['measured_winner']}, model winner "
              f"{study['model_winner']}")
    if os.path.isdir(RESULTS):
        print("# legacy dry-run table (results/dryrun)")
        dr = run("single")
        common.emit(dr, [
            "name", "us_per_call", "status", "t_compute_ms", "t_memory_ms",
            "t_mem_ub_ms", "t_collective_ms", "bottleneck", "roofline_frac",
            "useful_flop_ratio", "mem_gb_per_dev", "fits_16g", "compile_s"])
    return rows


def json_extra() -> dict:
    """Per-point measured/model rows, the equal-VMEM winners and the
    footprint-vs-time Pareto fronts, for ``run.py --json`` (schema >= 4)."""
    return _LAST_EXTRA


def perf_stats() -> dict:
    """Pallas-side compile/dispatch counts for the run.py suite record
    (the simulator counters never see these kernels)."""
    return dict(_STATS)


if __name__ == "__main__":
    main()
