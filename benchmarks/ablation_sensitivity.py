"""Ablation (beyond-paper): is the paper's "8 registers suffice" conclusion
robust to the memory system?  Sweeps main-memory latency (Table 1 gives a
1-5 cycle range; we extend to 10) and L1D capacity, and reports the cVRF-8
performance (normalised to the full VRF under the SAME machine).

If dispersion relied on a fast memory system, slow memories would break it;
the result shows the conclusion is latency-robust because spill/fill
traffic is tiny and L1-resident."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import simulator

APPS = ("pathfinder", "gemv", "dropout", "flashattention2")


def run(max_events=None, fold=True) -> list[dict]:
    rows = []
    sweep = simulator.SweepConfig.make([8, 32])
    for mem_lat in (1, 3, 5, 10):
        for l1_kb in (4, 16):
            t0 = time.time()
            m = simulator.MachineParams(
                l1_sets=l1_kb * 1024 // 32 // 2, mem_latency=mem_lat)
            out = common.sweep_grid(APPS, sweep, fold=fold,
                                    max_events=max_events, machine=m)
            us_each = (time.time() - t0) * 1e6 / len(APPS)
            for pi, name in enumerate(APPS):
                rows.append(dict(
                    name=f"{name}_mem{mem_lat}_l1_{l1_kb}k",
                    us_per_call=round(us_each, 1),
                    perf_cvrf8=round(float(out["cycles"][pi, 1])
                                     / float(out["cycles"][pi, 0]), 4),
                    hit_rate=round(float(out["hit_rate"][pi, 0]), 4),
                ))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "perf_cvrf8", "hit_rate"])
    return rows


if __name__ == "__main__":
    main()
