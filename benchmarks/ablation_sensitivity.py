"""Ablation (beyond-paper): is the paper's "8 registers suffice" conclusion
robust to the memory system?  Sweeps main-memory latency (Table 1 gives a
1-5 cycle range; we extend to 10) and L1D capacity, and reports the cVRF-8
performance (normalised to the full VRF under the SAME machine).

If dispersion relied on a fast memory system, slow memories would break it;
the result shows the conclusion is latency-robust because spill/fill
traffic is tiny and L1-resident.

Machine grid shape: the memory latencies are *traced* machine axes
(``simulator.MachineSweep``), so each L1 geometry's whole latency grid is
ONE ``sweep_grid`` call — the machine axis rides inside the vmapped grid
(one XLA dispatch per program on CPU, ``batch_programs=True`` for literally
one; either way ONE compile per program-shape bucket, where the old static
``MachineParams`` recompiled per latency point).  The per-point affine
cross-check (``costmodel.check_machine_affine``) certifies the traced grid
against the analytic machine model on every run.
"""

from __future__ import annotations

import time

from benchmarks import common
from repro.core import costmodel, simulator

APPS = ("pathfinder", "gemv", "dropout", "flashattention2")
MEM_LATENCIES = (1, 3, 5, 10)
L1_KBYTES = (4, 16)


def machine_grid(l1_kb: int) -> simulator.MachineSweep:
    """The traced latency axis for one (static) L1 capacity."""
    return simulator.MachineSweep.make(
        MEM_LATENCIES, l1_sets=l1_kb * 1024 // 32 // 2)


def run(max_events=None, fold=True, check_affine=True) -> list[dict]:
    rows = []
    sweep = simulator.SweepConfig.make([8, 32])
    for l1_kb in L1_KBYTES:
        machines = machine_grid(l1_kb)
        t0 = time.time()
        out = common.sweep_grid(APPS, sweep, fold=fold,
                                max_events=max_events, machine=machines)
        us_each = (time.time() - t0) * 1e6 / (len(APPS) * len(machines))
        if check_affine:
            costmodel.check_machine_affine(out, machines)
        for mi, mem_lat in enumerate(MEM_LATENCIES):
            for pi, name in enumerate(APPS):
                rows.append(dict(
                    name=f"{name}_mem{mem_lat}_l1_{l1_kb}k",
                    kernel=name, mem_latency=mem_lat, l1_kb=l1_kb,
                    us_per_call=round(us_each, 1),
                    cycles=int(out["cycles"][pi, 0, mi]),
                    perf_cvrf8=round(float(out["cycles"][pi, 1, mi])
                                     / float(out["cycles"][pi, 0, mi]), 4),
                    hit_rate=round(float(out["hit_rate"][pi, 0, mi]), 4),
                ))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "perf_cvrf8", "hit_rate"])
    return rows


if __name__ == "__main__":
    main()
