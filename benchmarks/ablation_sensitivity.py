"""Ablation (beyond-paper): is the paper's "8 registers suffice" conclusion
robust to the memory system?  Sweeps main-memory latency (Table 1 gives a
1-5 cycle range; we extend to 10) and L1D capacity, and reports the cVRF-8
performance (normalised to the full VRF under the SAME machine).

If dispersion relied on a fast memory system, slow memories would break it;
the result shows the conclusion is latency-robust because spill/fill
traffic is tiny and L1-resident.

Sweep shape: ONE declarative ``repro.api.Sweep`` covers the whole study —
``l1_geometry`` is a first-class axis, so the static L1 capacities that
used to need a hand-rolled outer loop are planned by the Session (one
engine build per geometry), while the memory latencies ride the traced
machine axes inside each dispatch (zero recompiles across latency values).
The per-point affine cross-check (``costmodel.check_machine_affine``)
certifies the traced grid against the analytic machine model on every run.
"""

from __future__ import annotations

from benchmarks import common
from repro import api
from repro.core import costmodel, simulator

APPS = ("pathfinder", "gemv", "dropout", "flashattention2")
MEM_LATENCIES = (1, 3, 5, 10)
L1_KBYTES = (4, 16)
GEOMETRIES = tuple(api.L1Geometry.from_kbytes(kb) for kb in L1_KBYTES)


def machine_grid(l1_kb: int) -> simulator.MachineSweep:
    """The traced latency axis for one (static) L1 capacity."""
    return simulator.MachineSweep.make(
        MEM_LATENCIES, l1_sets=l1_kb * 1024 // 32 // 2)


def run(max_events=None, fold=True, check_affine=True,
        session=None) -> list[dict]:
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=APPS, capacity=[8, 32],
                           mem_latency=MEM_LATENCIES,
                           l1_geometry=GEOMETRIES,
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / (len(APPS) * len(MEM_LATENCIES) * len(L1_KBYTES))
    if check_affine:
        for l1_kb in L1_KBYTES:
            costmodel.check_machine_affine(
                res.to_grid(l1_geometry=api.L1Geometry.from_kbytes(l1_kb)),
                machine_grid(l1_kb))
    rows = []
    for l1_kb in L1_KBYTES:
        geo = api.L1Geometry.from_kbytes(l1_kb)
        for mem_lat in MEM_LATENCIES:
            for name in APPS:
                pt = dict(kernel=name, mem_latency=mem_lat, l1_geometry=geo)
                rows.append(dict(
                    name=f"{name}_mem{mem_lat}_l1_{l1_kb}k",
                    kernel=name, mem_latency=mem_lat, l1_kb=l1_kb,
                    us_per_call=round(us_each, 1),
                    cycles=res.value("cycles", capacity=8, **pt),
                    perf_cvrf8=round(res.value("cycles", capacity=32, **pt)
                                     / res.value("cycles", capacity=8, **pt),
                                     4),
                    hit_rate=round(res.value("hit_rate", capacity=8, **pt),
                                   4),
                ))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "perf_cvrf8", "hit_rate"])
    return rows


if __name__ == "__main__":
    main()
