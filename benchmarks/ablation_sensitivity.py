"""Ablation (beyond-paper): is the paper's "8 registers suffice" conclusion
robust to the memory system?  Sweeps main-memory latency (Table 1 gives a
1-5 cycle range; we extend to 10) and L1D capacity, and reports the cVRF-8
performance (normalised to the full VRF under the SAME machine).

If dispersion relied on a fast memory system, slow memories would break it;
the result shows the conclusion is latency-robust because spill/fill
traffic is tiny and L1-resident."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import simulator

APPS = ("pathfinder", "gemv", "dropout", "flashattention2")


def run(max_events=400_000) -> list[dict]:
    rows = []
    for name in APPS:
        ev = common.events_for(name)
        for mem_lat in (1, 3, 5, 10):
            for l1_kb in (4, 16):
                t0 = time.time()
                m = simulator.MachineParams(
                    l1_sets=l1_kb * 1024 // 32 // 2, mem_latency=mem_lat)
                out = simulator.simulate_sweep(
                    ev, simulator.SweepConfig.make([8, 32]), m,
                    max_events=max_events)
                rows.append(dict(
                    name=f"{name}_mem{mem_lat}_l1_{l1_kb}k",
                    us_per_call=round((time.time() - t0) * 1e6, 1),
                    perf_cvrf8=round(float(out["cycles"][1])
                                     / float(out["cycles"][0]), 4),
                    hit_rate=round(float(out["hit_rate"][0]), 4),
                ))
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "perf_cvrf8", "hit_rate"])


if __name__ == "__main__":
    main()
