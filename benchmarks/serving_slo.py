"""Serving SLO study: the paper's compact-pool bet measured while the
system is failing.

Sweeps hot-pool size x eviction policy x traffic mix x fault profile over
the dispersed-KV serving engine (`repro.serve`): every grid point runs one
seeded, replayable scenario on the virtual clock — Poisson or bursty MMPP
arrivals, per-request deadlines, and (optionally) injected latency spikes,
a transient slot failure and a live hot-pool shrink.  The per-point
:class:`repro.serve.slo.SLOReport` rows ride :class:`repro.api.SweepResult`
(``from_table``), so the Pareto front of fast-memory footprint vs decode
latency comes from the same ``pareto()`` the cVRF studies use — and the
derived SLO metrics (``slo_attainment``, ``goodput``,
``degraded_throughput_ratio``) come from the ``repro.metrics`` registry.

``--max-events N`` is the budget knob: it caps engine steps per point and
scales the request count; at N <= 200 the grid also trims to the smoke
roster (2 hot-pool sizes x FIFO x steady x {none, chaos}).
"""

from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks import common
from repro import api
from repro.configs import registry
from repro.core import policies
from repro.models import get_model
from repro.serve import (FAULT_PROFILES, TRAFFIC_MIXES, FaultInjector,
                         ServeEngine, generate, slo)

ARCH = "phi3-mini-3.8b"      # dense GQA: the paged-KV layout
SLOTS = 2
MAX_LEN = 48
PAGE_SIZE = 8
DEADLINE = 150.0             # ticks per admission attempt
SEED = 0

HOT_PAGES = (6, 10, 16)
POLICIES = (policies.FIFO, policies.LRU)
MIXES = ("steady", "bursty")
FAULTS = ("none", "chaos")

SMOKE_HOT_PAGES = (6, 16)

_LAST_EXTRA: dict = {}


def _scenario(mix: str, n_requests: int, vocab: int):
    cfg = dataclasses.replace(
        TRAFFIC_MIXES[mix], n_requests=n_requests, max_len=MAX_LEN,
        vocab=vocab, deadline=DEADLINE)
    return generate(cfg, seed=SEED)


def run(max_events: int | None = None) -> tuple[api.SweepResult,
                                                list[dict]]:
    """Execute the sweep; returns (labeled grid, flat rows)."""
    smoke = max_events is not None and max_events <= 200
    hot_sizes = SMOKE_HOT_PAGES if smoke else HOT_PAGES
    pols = (policies.FIFO,) if smoke else POLICIES
    mixes = ("steady",) if smoke else MIXES
    n_requests = max(3, max_events // 40) if max_events else 12
    max_steps = max_events if max_events else 50_000

    cfg = registry.get(ARCH).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(SEED))
    decode = jax.jit(model.decode_step)     # shared: one compile, 24 points

    scenarios = {m: _scenario(m, n_requests, cfg.vocab_size) for m in mixes}
    rows = []
    for mix in mixes:
        scen = scenarios[mix]
        horizon = scen.horizon + 20 * n_requests
        for hot in hot_sizes:
            for pol in pols:
                for fault in FAULTS:
                    t0 = time.time()
                    eng = ServeEngine(
                        cfg, params, slots=SLOTS, max_len=MAX_LEN,
                        kv_mode="dispersed", page_size=PAGE_SIZE,
                        hot_pages=hot, pool_policy=pol, model=model,
                        decode_fn=decode, seed=SEED)
                    profile = FAULT_PROFILES[fault](
                        horizon, SLOTS, hot, seed=SEED)
                    reqs = eng.serve(scen, chaos=FaultInjector(profile),
                                     max_steps=max_steps)
                    rep = slo.summarize(eng, reqs)
                    rows.append(dict(
                        hot_pages=hot, policy=pol, traffic=mix,
                        fault=fault,
                        us_per_call=round((time.time() - t0) * 1e6, 1),
                        **rep.to_row()))
    axes = dict(hot_pages=hot_sizes, policy=pols, traffic=mixes,
                fault=FAULTS)
    result = api.SweepResult.from_table(axes, rows)
    result = result.derive("slo_attainment").derive("goodput") \
                   .derive("degraded_throughput_ratio")
    return result, rows


def main(max_events: int | None = None) -> list[dict]:
    global _LAST_EXTRA
    result, rows = run(max_events=max_events)
    # footprint vs latency: the serving restatement of the paper's
    # capacity-vs-cycles front, under faults and fault-free
    fronts = {}
    for fault in FAULTS:
        fronts[fault] = dict(
            p50=result.pareto("hot_bytes", "p50_decode_ticks", fault=fault),
            p99=result.pareto("hot_bytes", "p99_decode_ticks", fault=fault),
        )
    _LAST_EXTRA = dict(
        pareto=fronts,
        axes={k: list(v) for k, v in
              dict(hot_pages=result.axis("hot_pages").values,
                   policy=[policies.POLICY_NAMES[p]
                           for p in result.axis("policy").values],
                   traffic=result.axis("traffic").values,
                   fault=result.axis("fault").values).items()},
    )
    out_rows = []
    for r in rows:
        out_rows.append(dict(
            name=(f"hot{r['hot_pages']}_"
                  f"{policies.POLICY_NAMES[r['policy']]}_"
                  f"{r['traffic']}_{r['fault']}"),
            us_per_call=r["us_per_call"],
            tokens_per_tick=round(r["tokens_per_tick"], 4),
            p50=round(r["p50_decode_ticks"], 3),
            p99=round(r["p99_decode_ticks"], 3),
            miss_rate=round(r["deadline_miss_rate"], 4),
            degraded_tps=round(r["degraded_tokens_per_tick"], 4),
            hot_kb=r["hot_bytes"] // 1024,
            done=r["n_done"], failed=r["n_failed"],
            rejected=r["n_rejected"], preempts=r["n_preemptions"]))
    common.emit(out_rows, ["name", "us_per_call", "tokens_per_tick", "p50",
                           "p99", "miss_rate", "degraded_tps", "hot_kb",
                           "done", "failed", "rejected", "preempts"])
    return out_rows


def json_extra() -> dict:
    """Per-suite JSON payload for ``run.py --json`` (schema >= 4): the
    footprint-vs-latency Pareto fronts and the sweep axes."""
    return _LAST_EXTRA


if __name__ == "__main__":
    main()
