"""Fig 6: equal-area comparison — Register Dispersion (cVRF of 8 x 256-bit)
vs a full 32-register VRF of reduced 64-bit vector length.

The narrow machine is modelled from the wide-machine simulation counters:
with VL/4, every vector instruction strip-mines into 4 (4x base-occupancy
and 4x loop overhead), while each 32-byte cacheline is now touched by four
8-byte accesses (1 miss + 3 extra hits per previously-missed line); the
narrow VRF holds all 32 registers so it has no dispersion stalls.  All
results are normalised to the full-size 32 x 256-bit VRF.
"""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv


def narrow_cycles(full: dict) -> float:
    """Cycles for the 32-reg x 64-bit VRF machine from wide-VRF counters."""
    l1_hits = float(full["l1_hits"])
    l1_miss = float(full["l1_misses"])
    mem_cycles = l1_hits * 1 + l1_miss * (1 + 5)
    compute_cycles = float(full["cycles"]) - mem_cycles
    # 4x strip-mine on compute/overhead; 4x accesses on memory, same misses.
    naccess = (l1_hits + l1_miss) * 4
    return 4.0 * compute_cycles + (naccess - l1_miss) * 1 + l1_miss * (1 + 5)


def run(max_events=None, fold=True, names=None, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=[8, 32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    rows = []
    for name in names:
        cvrf8 = float(res.value("cycles", kernel=name, capacity=8))
        full = float(res.value("cycles", kernel=name, capacity=32))
        narrow = narrow_cycles({k: res.value(k, kernel=name, capacity=32)
                                for k in res.keys()})
        rows.append(dict(
            name=name, us_per_call=round(us_each, 1),
            dispersion_8x256=round(full / cvrf8, 3),
            narrow_32x64=round(full / narrow, 3),
            advantage=round(narrow / cvrf8, 2),
        ))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "dispersion_8x256",
                       "narrow_32x64", "advantage"])
    return rows


if __name__ == "__main__":
    main()
