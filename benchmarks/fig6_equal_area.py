"""Fig 6: equal-area comparison — Register Dispersion (cVRF of 8 x 256-bit)
vs a full 32-register VRF of reduced 64-bit vector length.

The narrow machine is the ``narrow_vrf_cycles`` model metric: with VL/4,
every vector instruction strip-mines into 4 (4x base-occupancy and 4x loop
overhead), while each 32-byte cacheline is now touched by four 8-byte
accesses (1 miss + 3 extra hits per previously-missed line); the narrow
VRF holds all 32 registers so it has no dispersion stalls.  L1 hit and
miss costs come from the sweep's machine axes (1 + ``l1_hit_cycles``, miss
adds ``mem_latency``), so equal-area results respond to machine-parameter
sweeps.  All columns are baseline-relative queries against the full-size
32 x 256-bit VRF (``baseline=dict(capacity=32)``).
"""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv

FULL = dict(capacity=32)


def run(max_events=None, fold=True, names=None, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=[8, 32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    r = (res.derive("speedup", baseline=FULL)
            .derive("narrow_vrf_speedup")
            .derive("equal_area_advantage", baseline=FULL))
    return [dict(
        name=name, us_per_call=round(us_each, 1),
        dispersion_8x256=round(r.value("speedup", kernel=name,
                                       capacity=8), 3),
        narrow_32x64=round(r.value("narrow_vrf_speedup", kernel=name,
                                   capacity=32), 3),
        advantage=round(r.value("equal_area_advantage", kernel=name,
                                capacity=8), 2),
    ) for name in names]


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "dispersion_8x256",
                       "narrow_32x64", "advantage"])
    return rows


if __name__ == "__main__":
    main()
