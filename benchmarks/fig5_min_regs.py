"""Fig 5: minimum cVRF capacity for a >95% hit rate, per application.
Paper's claim: 8 registers suffice for (almost) all; FlashAttention-2 needs
only 3 despite touching all 32 architectural registers."""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv

PAPER_MIN = {  # read off the paper's Fig 5
    "pathfinder": 6, "jacobi2d": 7, "somier": 8, "gemv": 5, "dropout": 3,
    "conv2d_7x7": 8, "densenet121_l105": 3, "resnet50_l10": 3,
    "flashattention2": 3,
}

CAPS = list(range(3, 17))


def run(max_events=None, fold=True, target=0.95, names=None,
        session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=CAPS + [32],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    rows = []
    for name in names:
        hit = {c: res.value("hit_rate", kernel=name, capacity=c)
               for c in CAPS}
        ok = [c for c in CAPS if hit[c] > target]
        min_regs = min(ok) if ok else max(CAPS) + 1
        rows.append(dict(
            name=name, us_per_call=round(us_each, 1),
            min_regs=min_regs, paper_min=PAPER_MIN.get(name, ""),
            active_regs=len(ses.built(name).program.active_vregs()),
            hit_at_min=round(hit.get(min_regs, 0.0), 4),
        ))
    return rows


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "min_regs", "paper_min",
                       "active_regs", "hit_at_min"])
    return rows


if __name__ == "__main__":
    main()
