"""Fig 5: minimum cVRF capacity for a >95% hit rate, per application.
Paper's claim: 8 registers suffice for (almost) all; FlashAttention-2 needs
only 3 despite touching all 32 architectural registers."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import planner

PAPER_MIN = {  # read off the paper's Fig 5
    "pathfinder": 6, "jacobi2d": 7, "somier": 8, "gemv": 5, "dropout": 3,
    "conv2d_7x7": 8, "densenet121_l105": 3, "resnet50_l10": 3,
    "flashattention2": 3,
}


def run(max_events=common.MAX_EVENTS) -> list[dict]:
    rows = []
    for name in rvv.BENCHMARKS:
        t0 = time.time()
        built = common.built(name)
        res = planner.min_registers_for_hit_rate(
            built.program, target=0.95, max_events=max_events)
        rows.append(dict(
            name=name, us_per_call=round((time.time() - t0) * 1e6, 1),
            min_regs=res.min_capacity, paper_min=PAPER_MIN.get(name, ""),
            active_regs=res.active_regs,
            hit_at_min=round(res.hit_rates.get(res.min_capacity, 0.0), 4),
        ))
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "min_regs", "paper_min",
                        "active_regs", "hit_at_min"])


if __name__ == "__main__":
    main()
