"""Beyond-paper: replacement-policy headroom — FIFO (paper) vs LRU / LFU /
Belady-OPT hit rates, plus the allocate-no-fetch write optimisation.

OPT upper-bounds any realizable policy; the FIFO->OPT gap quantifies what
the paper's simplicity choice leaves on the table (§5 of EXPERIMENTS.md).

The whole study — applications x capacities x policies x no-fetch — is one
sweep-grid call on folded traces.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import policies, simulator

CAPS = (4, 6, 8)
APPS = ("pathfinder", "jacobi2d", "gemv", "somier", "conv2d_7x7",
        "flashattention2")
POLS = (policies.FIFO, policies.LRU, policies.LFU, policies.OPT)


def run(max_events=None, fold=True) -> list[dict]:
    # Config axis: every (cap, policy) plus FIFO+allocate-no-fetch per cap.
    caps, pols, anfs = [], [], []
    for cap in CAPS:
        for pol in POLS:
            caps.append(cap), pols.append(pol), anfs.append(False)
        caps.append(cap), pols.append(policies.FIFO), anfs.append(True)
    sweep = simulator.SweepConfig(np.asarray(caps, np.int32),
                                  np.asarray(pols, np.int32),
                                  np.asarray(anfs, bool))
    t0 = time.time()
    out = common.sweep_grid(APPS, sweep, fold=fold, max_events=max_events)
    us_each = (time.time() - t0) * 1e6 / len(APPS)
    n_per_cap = len(POLS) + 1
    rows = []
    for pi, name in enumerate(APPS):
        for ki, cap in enumerate(CAPS):
            base = ki * n_per_cap
            row = dict(name=name, capacity=cap,
                       us_per_call=round(us_each, 1))
            for li, pol in enumerate(POLS):
                row[policies.POLICY_NAMES[pol]] = round(
                    float(out["hit_rate"][pi, base + li]), 4)
            row["fifo_cycles"] = int(out["cycles"][pi, base])
            row["fifo_no_fetch_cycles"] = int(
                out["cycles"][pi, base + len(POLS)])
            rows.append(row)
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "capacity", "fifo", "lru",
                       "lfu", "opt", "fifo_cycles", "fifo_no_fetch_cycles"])
    return rows


if __name__ == "__main__":
    main()
