"""Beyond-paper: replacement-policy headroom — FIFO (paper) vs LRU / LFU /
Belady-OPT hit rates, plus the allocate-no-fetch write optimisation.

OPT upper-bounds any realizable policy; the FIFO->OPT gap quantifies what
the paper's simplicity choice leaves on the table (§5 of EXPERIMENTS.md)."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import policies, simulator

CAPS = (4, 6, 8)
APPS = ("pathfinder", "jacobi2d", "gemv", "somier", "conv2d_7x7",
        "flashattention2")


def run(max_events=common.MAX_EVENTS) -> list[dict]:
    rows = []
    for name in APPS:
        t0 = time.time()
        ev = common.events_for(name)
        for cap in CAPS:
            row = dict(name=name, capacity=cap,
                       us_per_call=round((time.time() - t0) * 1e6, 1))
            for pol in (policies.FIFO, policies.LRU, policies.LFU,
                        policies.OPT):
                out = simulator.simulate_one(ev, cap, pol,
                                             max_events=max_events)
                row[policies.POLICY_NAMES[pol]] = round(
                    float(out["hit_rate"]), 4)
                if pol == policies.FIFO:
                    row["fifo_cycles"] = int(out["cycles"])
            anf = simulator.simulate_one(ev, cap, policies.FIFO, True,
                                         max_events=max_events)
            row["fifo_no_fetch_cycles"] = int(anf["cycles"])
            rows.append(row)
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "capacity", "fifo", "lru",
                        "lfu", "opt", "fifo_cycles",
                        "fifo_no_fetch_cycles"])


if __name__ == "__main__":
    main()
