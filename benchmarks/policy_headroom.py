"""Beyond-paper: replacement-policy headroom — FIFO (paper) vs LRU / LFU /
Belady-OPT hit rates, plus the allocate-no-fetch write optimisation.

OPT upper-bounds any realizable policy; the FIFO->OPT gap quantifies what
the paper's simplicity choice leaves on the table.

The whole study — applications x capacities x policies x no-fetch — is one
declarative ``repro.api.Sweep`` on folded traces, using the zipped
``config_points`` axis (the per-capacity FIFO+no-fetch extra column is not
a cartesian product).  The headroom and no-fetch columns are
baseline-relative metric queries: ``baseline=dict(policy="fifo",
alloc_no_fetch=False)`` aligns every zipped config point against the FIFO
point of the *same capacity*, so ``delta``/``speedup`` broadcast per
capacity without any per-point arithmetic.
"""

from __future__ import annotations

from benchmarks import common
from repro import api
from repro.core import policies

CAPS = (4, 6, 8)
APPS = ("pathfinder", "jacobi2d", "gemv", "somier", "conv2d_7x7",
        "flashattention2")
POLS = (policies.FIFO, policies.LRU, policies.LFU, policies.OPT)

FIFO_BASE = dict(policy="fifo", alloc_no_fetch=False)


def config_points() -> list[api.ConfigPoint]:
    """Every (cap, policy) plus FIFO+allocate-no-fetch per capacity."""
    pts = []
    for cap in CAPS:
        pts.extend(api.ConfigPoint(cap, pol) for pol in POLS)
        pts.append(api.ConfigPoint(cap, policies.FIFO, True))
    return pts


def run(max_events=None, fold=True, session=None) -> list[dict]:
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=APPS, config_points=config_points(),
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(APPS)
    r = (res.derive("delta", of="hit_rate", baseline=FIFO_BASE,
                    out="hit_rate_gain")
            .derive("speedup", baseline=FIFO_BASE))
    rows = []
    for name in APPS:
        for cap in CAPS:
            row = dict(name=name, capacity=cap,
                       us_per_call=round(us_each, 1))
            for pol in POLS:
                row[policies.POLICY_NAMES[pol]] = round(
                    r.value("hit_rate", kernel=name, capacity=cap,
                            policy=pol, alloc_no_fetch=False), 4)
            row["opt_headroom"] = round(
                r.value("hit_rate_gain", kernel=name, capacity=cap,
                        policy=policies.OPT, alloc_no_fetch=False), 4)
            row["fifo_cycles"] = r.value(
                "cycles", kernel=name, capacity=cap, policy=policies.FIFO,
                alloc_no_fetch=False)
            row["fifo_no_fetch_cycles"] = r.value(
                "cycles", kernel=name, capacity=cap, alloc_no_fetch=True)
            row["no_fetch_speedup"] = round(
                r.value("speedup", kernel=name, capacity=cap,
                        alloc_no_fetch=True), 4)
            rows.append(row)
    return rows


def main(max_events=None):
    rows = run(max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "capacity", "fifo", "lru",
                       "lfu", "opt", "opt_headroom", "fifo_cycles",
                       "fifo_no_fetch_cycles", "no_fetch_speedup"])
    return rows


if __name__ == "__main__":
    main()
