"""Fig 2 / Fig 7 / §4.4.1: analytic area model — baseline breakdown
calibration and the cVRF savings *predictions* vs the paper's synthesis.

Calibrated on the baseline only (VRF = 61% of VPU; VPU = 43.4% of CPU+VPU,
derived from 53% VPU saving => 23% total saving).  The savings rows are
model outputs to be compared against the paper's 3.5x / 53% / 23% — all
five come from one ``repro.metrics.area_headline`` query."""

from __future__ import annotations

from benchmarks import common
from repro import metrics

PAPER = dict(baseline_vrf_pct_of_vpu=61.0, baseline_vpu_pct_of_total=43.4,
             vrf_area_reduction_x=3.5, vpu_area_saving_pct=53.0,
             total_area_saving_pct=23.0)


def run() -> list[dict]:
    head = metrics.area_headline(n_full=32, n_cvrf=8)
    return [dict(name=name, us_per_call=0.0, value=round(value, 2),
                 paper=PAPER[name])
            for name, value in head.items()]


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "value", "paper"])
    return rows


if __name__ == "__main__":
    main()
