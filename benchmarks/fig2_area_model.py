"""Fig 2 / Fig 7 / §4.4.1: analytic area model — baseline breakdown
calibration and the cVRF savings *predictions* vs the paper's synthesis.

Calibrated on the baseline only (VRF = 61% of VPU; VPU = 43.4% of CPU+VPU,
derived from 53% VPU saving => 23% total saving).  The savings rows are
model outputs to be compared against the paper's 3.5x / 53% / 23% — all
five come from one ``repro.metrics.area_headline`` query.

Beyond the paper rows, the suite now reports the :mod:`repro.silicon`
macro registry at the 16 KB reference L1 macro (512 lines x 256 b):
per-backend macro area and per-access energy, plus each backend's area
ratio against the legacy ``flop`` constants — the calibration table
``docs/silicon.md`` documents, emitted through the same registry the DSE
driver sweeps."""

from __future__ import annotations

from benchmarks import common
from repro import metrics, silicon

PAPER = dict(baseline_vrf_pct_of_vpu=61.0, baseline_vpu_pct_of_total=43.4,
             vrf_area_reduction_x=3.5, vpu_area_saving_pct=53.0,
             total_area_saving_pct=23.0)

# The reference macro geometry the registry catalog is quoted at: a
# 2-way 16 KB L1 = 512 lines of 256 bits.
REF_WORDS, REF_BITS = 512, 256


def run() -> list[dict]:
    head = metrics.area_headline(n_full=32, n_cvrf=8)
    rows = [dict(name=name, us_per_call=0.0, value=round(value, 2),
                 paper=PAPER[name])
            for name, value in head.items()]
    # the macro-model calibration rows, through the silicon registry
    cat = silicon.macro_catalog(words=REF_WORDS, bits=REF_BITS)
    flop_area = cat["flop"]["area_au"]
    for name, rec in cat.items():
        rows.append(dict(
            name=f"l1_16kb_macro_area_au[{name}]", us_per_call=0.0,
            value=round(rec["area_au"], 1),
            vs_flop=round(rec["area_au"] / flop_area, 3)))
        rows.append(dict(
            name=f"l1_16kb_access_energy[{name}]", us_per_call=0.0,
            value=round(rec["access_energy"], 2)))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "value", "paper", "vs_flop"])
    return rows


if __name__ == "__main__":
    main()
