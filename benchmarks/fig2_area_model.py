"""Fig 2 / Fig 7 / §4.4.1: analytic area model — baseline breakdown
calibration and the cVRF savings *predictions* vs the paper's synthesis.

Calibrated on the baseline only (VRF = 61% of VPU; VPU = 43.4% of CPU+VPU,
derived from 53% VPU saving => 23% total saving).  The savings rows are
model outputs to be compared against the paper's 3.5x / 53% / 23%."""

from __future__ import annotations

from benchmarks import common
from repro.core import costmodel


def run() -> list[dict]:
    full = costmodel.cpu_area(32, dispersed=False)
    cvrf = costmodel.cpu_area(8, dispersed=True)   # + pinned v0 internally
    rows = [
        dict(name="baseline_vrf_pct_of_vpu",
             value=round(100 * full.vrf / full.vpu, 1), paper=61.0),
        dict(name="baseline_vpu_pct_of_total",
             value=round(100 * full.vpu / full.total, 1), paper=43.4),
        dict(name="vrf_area_reduction_x",
             value=round(full.vrf / (cvrf.vrf + cvrf.dispersion_overhead),
                         2), paper=3.5),
        dict(name="vpu_area_saving_pct",
             value=round(100 * (1 - cvrf.vpu / full.vpu), 1), paper=53.0),
        dict(name="total_area_saving_pct",
             value=round(100 * (1 - cvrf.total / full.total), 1), paper=23.0),
    ]
    for r in rows:
        r["us_per_call"] = 0.0
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "value", "paper"])
    return rows


if __name__ == "__main__":
    main()
