"""Table 3: VPU (full VRF) speedup over scalar execution, active vector
registers, and VRF utilisation — side by side with the paper's numbers."""

from __future__ import annotations

import time

from benchmarks import common
from repro import rvv
from repro.core import isa, simulator


def run(max_events=common.MAX_EVENTS) -> list[dict]:
    rows = []
    for name, b in rvv.BENCHMARKS.items():
        t0 = time.time()
        built = common.built(name)
        ev = common.events_for(name)
        scale = max(ev.num_events / max_events, 1.0)
        out = simulator.full_vrf_baseline(ev, max_events=max_events)
        vec_cycles = float(out["cycles"]) * scale
        scal_cycles = b.scalar_cost(**b.paper_params).cycles()
        paper = rvv.PAPER_TABLE3[name]
        active = len(built.program.active_vregs())
        rows.append(dict(
            name=name,
            us_per_call=round((time.time() - t0) * 1e6, 1),
            speedup=round(scal_cycles / vec_cycles, 2),
            paper_speedup=paper["speedup"],
            active_regs=active, paper_active=paper["active_regs"],
            vrf_util=round(active / isa.NUM_ARCH_VREGS, 2),
            paper_util=paper["util"],
            vec_cycles=int(vec_cycles), scalar_cycles=int(scal_cycles),
        ))
    return rows


def main():
    common.emit(run(), ["name", "us_per_call", "speedup", "paper_speedup",
                        "active_regs", "paper_active", "vrf_util",
                        "paper_util", "vec_cycles", "scalar_cycles"])


if __name__ == "__main__":
    main()
