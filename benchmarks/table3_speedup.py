"""Table 3: VPU (full VRF) speedup over scalar execution, active vector
registers, and VRF utilisation — side by side with the paper's numbers.

All applications share one declarative full-VRF sweep through ``repro.api``
(folded traces: cycle totals are extrapolated exactly for steady-state
kernels instead of the old scaled prefix).  The speedup column is the
``scalar_speedup`` metric — the analytic ``ScalarCost`` baseline per
kernel over truncation-corrected ``scaled_cycles`` — so the table carries
no hand-rolled counter arithmetic.
"""

from __future__ import annotations

from benchmarks import common
from repro import api, rvv
from repro.core import isa


def run(max_events=None, fold=True, names=None, session=None) -> list[dict]:
    names = list(names or rvv.BENCHMARKS)
    ses = session or api.default_session()
    res, dt = common.timed(
        ses.run, api.Sweep(kernels=names, capacity=[isa.NUM_ARCH_VREGS],
                           fold=fold, max_events=max_events))
    us_each = dt * 1e6 / len(names)
    r = res.derive("scalar_speedup")    # pulls scalar_cycles+scaled_cycles
    rows = []
    for name in names:
        # Beyond-paper kernels (conv2d_batched, mha) have no Table 3 row.
        paper = rvv.PAPER_TABLE3.get(name, dict(speedup="", active_regs="",
                                                util=""))
        active = len(ses.built(name).program.active_vregs())
        rows.append(dict(
            name=name, us_per_call=round(us_each, 1),
            speedup=round(r.value("scalar_speedup", kernel=name), 2),
            paper_speedup=paper["speedup"],
            active_regs=active, paper_active=paper["active_regs"],
            vrf_util=round(active / isa.NUM_ARCH_VREGS, 2),
            paper_util=paper["util"],
            vec_cycles=int(r.value("scaled_cycles", kernel=name)),
            scalar_cycles=int(r.value("scalar_cycles", kernel=name)),
        ))
    return rows


def main(names=None, max_events=None):
    rows = run(names=names, max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "speedup", "paper_speedup",
                       "active_regs", "paper_active", "vrf_util",
                       "paper_util", "vec_cycles", "scalar_cycles"])
    return rows


if __name__ == "__main__":
    main()
