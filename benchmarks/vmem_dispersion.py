"""Beyond-paper TPU analogue: HBM traffic vs VMEM accumulator working-set
size for the dispersed GEMM kernel (kernels/dispersed_gemm.py) — the cVRF
height/traffic trade-off (Fig 4's economics) at the VMEM<->HBM boundary.

Numeric correctness of both schedules is covered by tests; this benchmark
reports the closed-form traffic model on a training-shaped GEMM
(M=8192 tokens x K=4096 x N=14336, granite-8b MLP) and a small timed
interpret-mode run."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def run() -> list[dict]:
    rows = []
    m, k, n = 8192, 4096, 14336
    for w in (1, 2, 4, 8, 16):
        t = ops.hbm_traffic_model(m, n, k, block_m=128, block_k=512,
                                  working_set=w)
        rows.append(dict(
            name=f"traffic_W{w}", us_per_call=0.0,
            grouped_gb=round(t["grouped"] / 1e9, 2),
            dispersed_gb=round(t["dispersed"] / 1e9, 2),
            ideal_gb=round(t["ideal"] / 1e9, 2),
            vmem_acc_mb=round(t["vmem_acc_bytes"] / 1e6, 2),
        ))
    # small numeric spot-check (interpret mode)
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    t0 = time.time()
    got = ops.matmul(a, b, working_set=2, block_m=128, block_k=256)
    want = a @ b
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append(dict(name="interpret_check", grouped_gb="", dispersed_gb="",
                     ideal_gb="", vmem_acc_mb="",
                     us_per_call=round((time.time() - t0) * 1e6, 1),
                     max_err=round(err, 6)))
    return rows


def main():
    rows = run()
    common.emit(rows, ["name", "us_per_call", "grouped_gb", "dispersed_gb",
                       "ideal_gb", "vmem_acc_mb", "max_err"])
    return rows


if __name__ == "__main__":
    main()
