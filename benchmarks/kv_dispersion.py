"""Beyond-paper serving analogue: dispersed KV page pool hit rates under a
decode access pattern, swept over hot-pool sizes — the Fig 4(b) curve
reproduced at KV-page granularity with the SAME policy code."""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import policies
from repro.serve import DispersedKVPool, PagePoolConfig


def _decode_trace(n_pages=64, steps=600, seed=0):
    """Paged-attention access pattern: every step touches the current tail
    page plus a few random history pages (sparse attention reads), with
    sinks (page 0) touched every step."""
    g = np.random.default_rng(seed)
    seq = []
    for t in range(steps):
        tail = min(t // 8, n_pages - 1)
        seq.append((0, False))                       # pinned sink
        seq.append((tail, True))                     # append new KV
        for p in g.integers(0, max(tail, 1), 3):
            seq.append((int(p), False))              # history reads
    return seq


WARMUP_STEPS = 40     # fills during cold start are not steady-state misses


def run(steps: int = 600) -> list[dict]:
    """``steps`` is the decode-trace length — the harness budget knob (the
    pool does one host->device dispatch per access, so wall time is linear
    in it); ``run.py --max-events`` forwards here.  The first
    ``WARMUP_STEPS`` prime the pool, then ``reset_stats()`` starts the
    measured steady-state window."""
    rows = []
    warm = min(WARMUP_STEPS, steps // 4)
    trace = _decode_trace(steps=steps)
    split = warm * 5      # the trace makes 5 pool accesses per step
    for hot in (4, 8, 16, 32):
        for pol, pname in ((policies.FIFO, "fifo"), (policies.LRU, "lru")):
            t0 = time.time()
            pool = DispersedKVPool(PagePoolConfig(
                num_logical_pages=64, num_hot_pages=hot,
                page_shape=(16, 2, 8), policy=pol))
            for i, (page, write) in enumerate(trace):
                if i == split:
                    pool.reset_stats()
                if write:
                    pool.write(page, pool.read(page) + 1)
                else:
                    pool.read(page)
            st = pool.stats()
            rows.append(dict(
                name=f"hot{hot}_{pname}",
                us_per_call=round((time.time() - t0) * 1e6, 1),
                hit_rate=round(st["hit_rate"], 4), spills=st["spills"],
                hot_kb=st["hot_bytes"] // 1024))
    return rows


def main(max_events: int | None = None):
    rows = run(steps=max_events if max_events else 600)
    common.emit(rows, ["name", "us_per_call", "hit_rate", "spills",
                       "hot_kb"])
    return rows


if __name__ == "__main__":
    main()
