"""Whole-network sweeps: registry models through the trace-from-model bridge.

The paper demonstrates register dispersion on hand-written kernels plus one
densenet layer; this suite generalises that to whole networks.  Each model
named in ``MODELS`` is lowered by :mod:`repro.bridge` — every layer's
concrete shapes become way-span-padded ``Assembler.repeat`` tile programs,
deduplicated by shape signature — and the union runs as ONE declarative
``Session.run`` over capacity x L1 geometry.  Folding keeps it tractable
(each layer is a certified period); the planner's shape-bucket grouping
keeps the compile count at (bucket x geometry), not (kernel x point).

Reported per (model, capacity, L1): the cVRF footprint, and network-level
cycle/energy totals — per-kernel tile counters scaled by each layer's
count x macro-factor (real work / tile work, ``docs/bridge.md``).
"""

from __future__ import annotations

from benchmarks import common
from repro import api, bridge

MODELS = ("granite-8b", "qwen3-8b", "falcon-mamba-7b",
          "recurrentgemma-2b", "deepseek-v2-lite-16b")
CAPS = (3, 4, 8, 12, 32)
L1_KBYTES = (4, 16)

_LAST_EXTRA: dict = {}


def run(models=MODELS, caps=CAPS, l1_kbytes=L1_KBYTES, max_events=None,
        fold=True, session=None) -> list[dict]:
    ses = session or api.default_session()
    sweep = api.Sweep(
        network=tuple(models), capacity=tuple(caps),
        l1_geometry=tuple(api.L1Geometry.from_kbytes(kb)
                          for kb in l1_kbytes),
        fold=fold, max_events=max_events)
    res, dt = common.timed(ses.run, sweep)
    res = res.derive("scaled_cycles").derive("energy")
    lowered = list(getattr(sweep, "_lowered"))
    us_each = dt * 1e6 / max(1, len(sweep.kernels))
    rows = []
    for r in bridge.network_report(res, lowered,
                                   metrics=("scaled_cycles", "energy")):
        rows.append(dict(
            name=r["model"], us_per_call=round(us_each, 1),
            capacity=r["capacity"], l1_kb=r["l1_kb"],
            footprint_bytes=r["footprint_bytes"], kernels=r["kernels"],
            instances=r["instances"],
            cycles_total=r["scaled_cycles_total"],
            energy_total=r["energy_total"],
        ))
    fe = res.data["fold_exact"]
    _LAST_EXTRA.clear()
    _LAST_EXTRA.update(
        networks=res.meta.get("networks", []),
        points=res.meta["points"], compiles=res.meta["compiles"],
        dispatches=res.meta["dispatches"],
        plan_groups=len({(g["l1_geometry"], g["bucket"])
                         for g in res.meta["plan"]}),
        fold_exact_fraction=float(fe.mean()),
        rows=rows,
    )
    return rows


def main(max_events: int | None = None) -> list[dict]:
    rows = run(max_events=max_events)
    common.emit(rows, ["name", "us_per_call", "capacity", "l1_kb",
                       "footprint_bytes", "kernels", "instances",
                       "cycles_total", "energy_total"])
    return rows


def json_extra() -> dict:
    """Per-model network payload for ``run.py --json`` (schema >= 5): the
    lowered-network summaries, plan/compile accounting and the per-point
    report rows."""
    return dict(_LAST_EXTRA)


if __name__ == "__main__":
    main()
