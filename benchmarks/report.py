"""Markdown report generator for EXPERIMENTS.md tables (dry-run + roofline).

  PYTHONPATH=src:. python -m benchmarks.report results/dryrun        # baseline
  PYTHONPATH=src:. python -m benchmarks.report results/dryrun_opt   # optimized
"""

from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs import SHAPES, get
from repro.launch import analytic


def load(d, mesh):
    cells = []
    for f in sorted(glob.glob(os.path.join(d, f"*_{mesh}.json"))):
        try:
            cells.extend(json.load(open(f)))
        except Exception:
            pass
    return cells


def dryrun_table(d):
    print("| arch | shape | 16x16 | 2x16x16 | mem GB/dev | fits 16G | "
          "compile s |")
    print("|---|---|---|---|---|---|---|")
    single = {(c["arch"], c["shape"]): c for c in load(d, "single")}
    multi = {(c["arch"], c["shape"]): c for c in load(d, "multi")}
    for key in sorted(single):
        s, m = single[key], multi.get(key, {})
        if s["status"] == "skip":
            print(f"| {key[0]} | {key[1]} | skip* | skip* | — | — | — |")
            continue
        print(f"| {key[0]} | {key[1]} | {s['status']} "
              f"| {m.get('status', '?')} "
              f"| {s.get('bytes_per_device', 0) / 1e9:.1f} "
              f"| {'yes' if s.get('fits_16g') else 'NO'} "
              f"| {s.get('compile_s', 0):.0f} |")


def roofline_table(d):
    print("| arch/shape | t_comp ms | t_mem ms | t_coll ms | bottleneck | "
          "roofline frac | MFU ub | useful ratio | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in load(d, "single"):
        if c["status"] != "ok":
            continue
        cfg = get(c["arch"])
        shape = SHAPES[c["shape"]]
        t = analytic.roofline_terms(c, cfg, shape)
        coll = (c.get("collectives") or {}).get("collective_bytes", 0)
        print(f"| {c['arch']}/{c['shape']} "
              f"| {t['t_compute'] * 1e3:.1f} | {t['t_memory'] * 1e3:.1f} "
              f"| {t['t_collective'] * 1e3:.1f} | {t['bottleneck']} "
              f"| {t['roofline_fraction']:.3f} "
              f"| {t['mfu_upper_bound']:.3f} "
              f"| {t['useful_flop_ratio']:.2f} | {coll / 1e9:.1f} |")


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    which = sys.argv[2] if len(sys.argv) > 2 else "both"
    if which in ("both", "dryrun"):
        dryrun_table(d)
    if which in ("both", "roofline"):
        print()
        roofline_table(d)
