"""Shared benchmark utilities — now a thin shim over the process-default
:class:`repro.api.Session`.

The two-level sweep cache this module used to own (module-global ``_BUILT``
/ ``_PREPARED`` dicts) lives in the Session now: trace preparation is keyed
by (name, params, fold, max_events, fold warm-up — a function of the static
L1 geometry only), and compiled executables live in XLA's jit cache, one
entry per (shape bucket, L1 geometry) signature.  Suites that still sweep
through this module share the default Session's caches; new code should
construct a :class:`repro.api.Sweep` and call ``Session.run`` directly.
"""

from __future__ import annotations

import time
import warnings

from repro import api
from repro.core import simulator

# The refine budget lives on the Session now: tune it via
# api.default_session().refine_max_rows (or a Session of your own).


def built(name):
    """Build (and cache) a paper-size benchmark trace."""
    return api.default_session().built(name)


def prepared_for(name, fold=True, max_events=None,
                 machine=simulator.DEFAULT_MACHINE) -> simulator.PreparedTrace:
    """Prepared (expanded + folded) trace per benchmark, session-cached.

    ``max_events`` truncation is deprecated here: declare the budget on a
    :class:`repro.api.Sweep` (``Sweep(max_events=...)``) instead.
    """
    if max_events is not None:
        warnings.warn(
            "prepared_for(max_events=...) is deprecated; pass max_events to "
            "a repro.api.Sweep (or Session.prepared) instead",
            DeprecationWarning, stacklevel=2)
    return api.default_session().prepared(name, fold=fold,
                                          max_events=max_events,
                                          machine=machine)


def sweep_grid(names, sweep, fold=True, max_events=None, refine=True,
               machine=simulator.DEFAULT_MACHINE):
    """One sweep call for a whole suite: P programs x C configs — and, when
    ``machine`` is a :class:`simulator.MachineSweep`, x M machine points in
    the same dispatch.  Delegates to ``Session.grid`` on the process-default
    session (which owns the caches and the fold/refine policy)."""
    return api.default_session().grid(names, sweep, machine=machine,
                                      fold=fold, max_events=max_events,
                                      refine=refine)


def emit(rows: list[dict], header: list[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
