"""Shared benchmark utilities: the two-level sweep cache and CSV emission.

Level 1 — *trace preparation* keyed by trace identity ``(name, fold,
max_events, warm_lines)``: building a benchmark, expanding it to
per-instruction event matrices and computing its periodic fold plan happens
once per process, no matter how many suites sweep it.  ``warm_lines`` (the
fold warm-up, a function of the static L1 geometry only) is part of the key
because suites sweeping different L1 sizes fold differently; the traced
latency axes never are.

Level 2 — *compiled executables* keyed by padded shape: the fused engine
pads every prepared trace to a power-of-two bucket and traces the
per-program ``spill_line0`` plus the whole (capacity, policy, machine)
config grid, so ``jax.jit``'s cache (one entry per (bucket, grid-size,
L1-geometry) signature) is shared across programs, suites and machine
points instead of recompiling per benchmark — or per machine — as the
per-event engine did.
"""

from __future__ import annotations

import time

from repro.core import folding, simulator

_BUILT = {}
_PREPARED = {}


def built(name):
    """Build (and cache) a paper-size benchmark trace."""
    from repro import rvv
    if name not in _BUILT:
        b = rvv.BENCHMARKS[name]
        _BUILT[name] = b.build(**b.paper_params)
    return _BUILT[name]


def prepared_for(name, fold=True, max_events=None,
                 machine=simulator.DEFAULT_MACHINE) -> simulator.PreparedTrace:
    """Level-1 cache: expanded (+folded/truncated) trace per benchmark."""
    if max_events is not None:
        fold = False                      # truncation is the legacy mode
    warm = folding.warm_lines_for(machine.l1_sets, machine.l1_ways)
    key = (name, fold, max_events, warm)
    if key not in _PREPARED:
        _PREPARED[key] = simulator.prepare(
            built(name).program, fold=fold, max_events=max_events,
            warm_lines=warm)
    return _PREPARED[key]


# A folded trace whose steadiness check fails is re-simulated in full when
# the full trace is affordable; bigger traces keep the (flagged) fold.
# Certified exact-outer plans (docs/folding.md) make this pass rarer: a
# kernel whose nested plan could not certify (jacobi2d's ping-pong, the
# batched/multi-head outer loops) now extrapolates exactly instead of
# re-running unfolded.
REFINE_MAX_ROWS = 400_000


def sweep_grid(names, sweep, fold=True, max_events=None, refine=True,
               machine=simulator.DEFAULT_MACHINE):
    """One sweep call for a whole suite: P programs x C configs — and, when
    ``machine`` is a :class:`simulator.MachineSweep`, x M machine points in
    the same dispatch (counter arrays gain a trailing machine axis).

    With ``refine`` (default), any program whose fold was not certified
    exact (``fold_exact`` False, at any grid point) and whose full trace
    has at most ``REFINE_MAX_ROWS`` instructions is transparently
    re-simulated without folding, so the suite is exact wherever exactness
    is affordable and honestly flagged where it is not.
    """
    names = list(names)
    preps = [prepared_for(n, fold=fold, max_events=max_events,
                          machine=machine)
             for n in names]
    out = simulator.simulate_grid(preps, sweep, machine)
    if fold and refine and "fold_exact" in out:
        for pi, name in enumerate(names):
            if out["fold_exact"][pi].all():
                continue
            if built(name).program.num_instructions > REFINE_MAX_ROWS:
                continue
            sub = simulator.simulate_grid(
                [prepared_for(name, fold=False, machine=machine)],
                sweep, machine)
            for k in out:
                out[k][pi] = sub[k][0] if k != "fold_exact" else True
    return out


def emit(rows: list[dict], header: list[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
