"""Shared benchmark utilities: build cache and CSV emission."""

from __future__ import annotations

import time

_BUILT = {}

# Event cap for the cycle simulator: the big GEMM/conv traces are periodic,
# so a multi-million-event prefix gives the same rates; cycle totals are
# scaled by the prefix ratio (exact for steady-state traces).
MAX_EVENTS = 1_500_000


def built(name):
    """Build (and cache) a paper-size benchmark trace."""
    from repro import rvv
    if name not in _BUILT:
        b = rvv.BENCHMARKS[name]
        _BUILT[name] = b.build(**b.paper_params)
    return _BUILT[name]


def events_for(name):
    from repro.core import events
    key = ("ev", name)
    if key not in _BUILT:
        _BUILT[key] = events.expand(built(name).program)
    return _BUILT[key]


def emit(rows: list[dict], header: list[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
